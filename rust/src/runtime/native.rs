//! Native execution engine: pure-Rust implementations of every artifact the
//! runtime serves (`fwd_*`, `fwd_fused_*`, `train_*`, `capture_*`,
//! `kernel_*`), numerically mirroring the JAX definitions in
//! `python/compile/model.py`.
//!
//! The transformer forward is parameterized over a [`ProjectionOps`]
//! provider so the same code drives three weight representations:
//!
//! * dense `W` matrices ([`DenseProj`], the `fwd_*` path),
//! * explicit `(Q, L, R)` triples computed as `x·Qᵀ + (x·Rᵀ)·Lᵀ` without
//!   ever forming `Q + L·R` ([`QlrDenseProj`], the `fwd_fused_*` path),
//! * bit-packed `Q` plus factors ([`crate::fused::FusedModel`], the
//!   serving hot path — dequantizes on the fly).
//!
//! ## Incremental decoding ([`KvCache`], [`fwd_prefill`], [`fwd_decode`])
//!
//! Generation serving never re-runs the full sequence per emitted token.
//! [`fwd_prefill`] is the ordinary causal forward over a prompt that
//! additionally stores each layer's post-RoPE `K` and raw `V` rows in a
//! per-session [`KvCache`]; [`fwd_decode`] then advances a *batch* of
//! sessions by one token each: embed the new tokens, project through the
//! same [`ProjectionOps`], rotate at each session's own position offset,
//! append one `K`/`V` row per layer, and attend over the cached rows only
//! — O(len) per step instead of the O(len²) full re-forward.
//!
//! Bit-exactness contract: every per-token operation (RMSNorm, projection
//! dot products, RoPE table entries, the scaled-softmax loop, the
//! attention-value accumulation, the MLP) is row-local with the identical
//! f32 operation order as [`forward_with`], so prefill logits equal the
//! full-sequence forward's logits **bit-for-bit**, and a decoded step's
//! logits equal the last row of a full forward over the extended sequence
//! bit-for-bit (tested below). Decode results are independent of which
//! other sessions share the step, which is what makes continuous batching
//! in `serve` sound.
//!
//! ## Paged storage
//!
//! A [`KvCache`] is either *flat* (private growable buffers) or *paged*
//! (a block table into a budgeted process-wide [`KvPool`] — fixed-size
//! pages, cross-session prefix sharing, copy-on-write, LRU reclaim; spec
//! in [`super::kvpool`]). The attention loops are storage-agnostic: they
//! read gathered per-head panels, so both backings produce bit-identical
//! logits. Growth is validated against a per-cache position cap and the
//! pool budget **before** compute; violations are typed
//! ([`super::kvpool::KvError`]) and leave the caches untouched, which is
//! what lets the serving scheduler preempt a session (drop its cache,
//! keep its token history) and later resume it bit-exactly by
//! re-prefilling.
//!
//! `train_*` is a full hand-derived reverse pass (RMSNorm, RoPE, causal
//! GQA attention, SwiGLU/GeGLU) plus the exact AdamW update from
//! `model.train_step`; gradients are checked against finite differences in
//! the tests below.

use std::borrow::Cow;
use std::collections::BTreeMap;
use std::f32::consts::PI;

use anyhow::{anyhow, bail, Result};

use super::kvpool::{BlockTable, KvError, KvPool};
use super::{FamilySpec, Manifest, Value};
use crate::model::ModelParams;
use crate::quant::{Quantizer as _, UniformQuantizer};
use crate::tensor::{matmul, matmul_nt, matmul_tn, Matrix};

const RMS_EPS: f32 = 1e-5;

// ---------------------------------------------------------------- params

/// Flat parameter list resolved to matrices, indexed by family layout.
/// Owns the matrices when built from [`Value`]s, or borrows them when the
/// caller already holds resolved matrices (the fused serving hot path, so
/// no per-batch parameter copies happen).
pub struct ParamView<'a> {
    pub fam: &'a FamilySpec,
    mats: Cow<'a, [Matrix]>,
}

impl<'a> ParamView<'a> {
    pub fn from_values(fam: &'a FamilySpec, values: &[Value]) -> Result<ParamView<'a>> {
        if values.len() != fam.params.len() {
            bail!(
                "family {} wants {} params, got {}",
                fam.name,
                fam.params.len(),
                values.len()
            );
        }
        let mats = values
            .iter()
            .map(|v| v.to_matrix())
            .collect::<Result<Vec<_>>>()?;
        Ok(ParamView {
            fam,
            mats: Cow::Owned(mats),
        })
    }

    pub fn from_params(params: &'a ModelParams) -> Result<ParamView<'a>> {
        ParamView::from_values(&params.family, &params.values)
    }

    /// Borrow pre-resolved matrices (must be in family layout order).
    pub fn from_slice(fam: &'a FamilySpec, mats: &'a [Matrix]) -> Result<ParamView<'a>> {
        if mats.len() != fam.params.len() {
            bail!(
                "family {} wants {} params, got {}",
                fam.name,
                fam.params.len(),
                mats.len()
            );
        }
        Ok(ParamView {
            fam,
            mats: Cow::Borrowed(mats),
        })
    }

    pub fn get(&self, name: &str) -> Result<&Matrix> {
        Ok(&self.mats[self.fam.param_index(name)?])
    }
}

/// How the transformer applies a (possibly compressed) projection matrix:
/// `project` computes `x · Wᵀ` for activations `x` of shape (tokens, in).
pub trait ProjectionOps {
    fn project(&self, name: &str, x: &Matrix) -> Result<Matrix>;
}

/// Dense weights straight out of a [`ParamView`].
pub struct DenseProj<'a> {
    pub view: &'a ParamView<'a>,
}

impl ProjectionOps for DenseProj<'_> {
    fn project(&self, name: &str, x: &Matrix) -> Result<Matrix> {
        Ok(matmul_nt(x, self.view.get(name)?))
    }
}

/// Explicit dense `(Q, L, R)` per projection; computes `x·Qᵀ + (x·Rᵀ)·Lᵀ`
/// without materializing `Q + L·R` (the `fwd_fused_*` artifact semantics).
pub struct QlrDenseProj {
    pub mats: BTreeMap<String, (Matrix, Matrix, Matrix)>,
}

impl ProjectionOps for QlrDenseProj {
    fn project(&self, name: &str, x: &Matrix) -> Result<Matrix> {
        let (q, l, r) = self
            .mats
            .get(name)
            .ok_or_else(|| anyhow!("no Q/L/R for projection '{name}'"))?;
        Ok(crate::fused::qlr_matmul_t(x, q, l, r))
    }
}

// ------------------------------------------------------------- primitives

/// Row-wise RMSNorm; returns the normalized rows and the per-row factor
/// `r_i = 1/√(mean(x_i²)+ε)` needed by the backward pass.
fn rms_norm(x: &Matrix, g: &[f32]) -> (Matrix, Vec<f32>) {
    let (t, d) = x.shape();
    assert_eq!(g.len(), d, "rms_norm gain length");
    let mut out = Matrix::zeros(t, d);
    let mut rs = vec![0f32; t];
    for i in 0..t {
        let row = x.row(i);
        let ms = row.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / d as f64;
        let r = 1.0 / ((ms as f32) + RMS_EPS).sqrt();
        rs[i] = r;
        let dst = out.row_mut(i);
        for j in 0..d {
            dst[j] = row[j] * r * g[j];
        }
    }
    (out, rs)
}

/// RMSNorm backward: given the forward inputs and `dy`, produce `dx` and
/// the gain gradient.
fn rms_backward(x: &Matrix, g: &[f32], r: &[f32], dy: &Matrix) -> (Matrix, Vec<f32>) {
    let (t, d) = x.shape();
    let mut dx = Matrix::zeros(t, d);
    let mut dg = vec![0f32; d];
    for i in 0..t {
        let xr = x.row(i);
        let dyr = dy.row(i);
        let ri = r[i];
        let mut dot = 0f64;
        for j in 0..d {
            dg[j] += dyr[j] * xr[j] * ri;
            dot += (dyr[j] as f64) * (g[j] as f64) * (xr[j] as f64);
        }
        // ∂r/∂x_j = -r³ x_j / d  ⇒  dx_j = r·dy_j·g_j − x_j·r³·(dy·g·x)/d
        let coef = ri * ri * ri * (dot as f32) / d as f32;
        let dxr = dx.row_mut(i);
        for j in 0..d {
            dxr[j] = ri * dyr[j] * g[j] - xr[j] * coef;
        }
    }
    (dx, dg)
}

/// Precomputed rotary-embedding tables for one (seq, head_dim) shape.
struct RopeTable {
    cos: Vec<f32>,
    sin: Vec<f32>,
    half: usize,
}

impl RopeTable {
    fn new(seq: usize, head_dim: usize, theta: f32) -> RopeTable {
        assert!(head_dim % 2 == 0, "rope needs even head_dim");
        let half = head_dim / 2;
        let mut cos = Vec::with_capacity(seq * half);
        let mut sin = Vec::with_capacity(seq * half);
        for t in 0..seq {
            for i in 0..half {
                let freq = theta.powf(-(i as f32) / half as f32);
                let ang = t as f32 * freq;
                cos.push(ang.cos());
                sin.push(ang.sin());
            }
        }
        RopeTable { cos, sin, half }
    }

    /// Rotate every head of every row in place. Rows are (batch·seq, H·hd)
    /// with position = row % seq. `inverse` applies the transpose rotation
    /// (exact inverse — used by the backward pass).
    fn apply(&self, m: &mut Matrix, seq: usize, inverse: bool) {
        let (rows, width) = m.shape();
        let hd = 2 * self.half;
        assert_eq!(width % hd, 0, "rope width");
        let nh = width / hd;
        for rix in 0..rows {
            let t = rix % seq;
            let row = m.row_mut(rix);
            for h in 0..nh {
                let base = h * hd;
                for i in 0..self.half {
                    let c = self.cos[t * self.half + i];
                    let mut s = self.sin[t * self.half + i];
                    if inverse {
                        s = -s;
                    }
                    let x1 = row[base + i];
                    let x2 = row[base + self.half + i];
                    row[base + i] = x1 * c - x2 * s;
                    row[base + self.half + i] = x1 * s + x2 * c;
                }
            }
        }
    }
}

/// Rotate one flattened activation row's heads at absolute position `pos`,
/// computing the table entries on the fly with the **exact arithmetic** of
/// [`RopeTable::new`]/[`RopeTable::apply`] — decode stays bit-identical to
/// the table-driven forward while paying O(head_dim) trig per row instead
/// of rebuilding an O(context · head_dim) table every step.
fn rope_rotate_row(row: &mut [f32], head_dim: usize, pos: usize, theta: f32) {
    assert!(head_dim % 2 == 0, "rope needs even head_dim");
    let half = head_dim / 2;
    debug_assert_eq!(row.len() % head_dim, 0, "rope width");
    let nh = row.len() / head_dim;
    for i in 0..half {
        let freq = theta.powf(-(i as f32) / half as f32);
        let ang = pos as f32 * freq;
        let c = ang.cos();
        let s = ang.sin();
        for h in 0..nh {
            let base = h * head_dim;
            let x1 = row[base + i];
            let x2 = row[base + half + i];
            row[base + i] = x1 * c - x2 * s;
            row[base + half + i] = x1 * s + x2 * c;
        }
    }
}

// --------------------------------------------------------------- kv cache

/// Per-session key/value cache for incremental decoding. `K` rows are
/// stored post-RoPE (rotated at their absolute position), `V` rows raw —
/// exactly the values the full-sequence attention would recompute, so
/// attending over the cache reproduces the causal forward bit-for-bit.
///
/// Two backings share one interface:
///
/// * **Flat** ([`KvCache::new`] / [`KvCache::for_family`]): one growable
///   (len × kv_dim) `K`/`V` buffer per layer, private to the session.
/// * **Paged** ([`KvCache::paged`]): a block table into a process-wide
///   [`KvPool`] — fixed-size pages under a hard byte budget, cross-session
///   prefix sharing with copy-on-write, LRU reclaim of released prompt
///   chains. See [`super::kvpool`] for the allocator spec. Storage layout
///   never changes the arithmetic: reads gather the identical f32 rows, so
///   both backings decode bit-identically.
///
/// Every cache enforces a position cap (`max_len`): growing past it is a
/// typed [`KvError::ContextOverflow`] from [`fwd_prefill`]/[`fwd_decode`]
/// instead of a silent decode at positions the model was never validated
/// at. Capacity (pages / COW copies) is reserved via [`ensure_capacity`]
/// *before* any forward compute, so a mid-step pool exhaustion leaves the
/// session unchanged and retryable.
///
/// [`ensure_capacity`]: KvCache::ensure_capacity
#[derive(Debug)]
pub struct KvCache {
    kv_dim: usize,
    n_layers: usize,
    /// Cached positions (tokens whose K/V rows are logically stored).
    len: usize,
    /// Hard cap on `len` (context validation; `usize::MAX` = uncapped).
    max_len: usize,
    backing: KvBacking,
}

#[derive(Debug)]
enum KvBacking {
    /// Per layer: (flat K rows, flat V rows), row-major (len × kv_dim).
    Flat(Vec<(Vec<f32>, Vec<f32>)>),
    Paged { pool: KvPool, table: BlockTable },
}

impl KvCache {
    pub fn new(n_layers: usize, kv_dim: usize) -> KvCache {
        KvCache {
            kv_dim: kv_dim.max(1),
            n_layers,
            len: 0,
            max_len: usize::MAX,
            backing: KvBacking::Flat(vec![(Vec::new(), Vec::new()); n_layers]),
        }
    }

    pub fn for_family(fam: &FamilySpec) -> KvCache {
        KvCache::new(fam.n_layers, fam.kv_dim())
    }

    /// A cache drawing its storage from `pool`, capped at `max_len`
    /// positions.
    pub fn paged(pool: &KvPool, max_len: usize) -> KvCache {
        KvCache {
            kv_dim: pool.kv_dim(),
            n_layers: pool.n_layers(),
            len: 0,
            max_len: max_len.max(1),
            backing: KvBacking::Paged {
                pool: pool.clone(),
                table: BlockTable::default(),
            },
        }
    }

    /// Cap the cache at `n` positions (builder style).
    pub fn with_max_len(mut self, n: usize) -> KvCache {
        self.max_len = n.max(1);
        self
    }

    /// Number of cached positions (tokens whose K/V rows are stored).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn max_len(&self) -> usize {
        self.max_len
    }

    /// The pool and block table behind a paged cache (`None` for flat
    /// backings). The serving loop's debug-mode auditor uses this to
    /// cross-check pool refcounts against the live tables.
    pub fn pool_and_table(&self) -> Option<(&KvPool, &BlockTable)> {
        match &self.backing {
            KvBacking::Flat(_) => None,
            KvBacking::Paged { pool, table } => Some((pool, table)),
        }
    }

    /// Positions adopted from the pool's prefix index (0 for flat caches
    /// and unshared sessions).
    pub fn shared_len(&self) -> usize {
        match &self.backing {
            KvBacking::Flat(_) => 0,
            KvBacking::Paged { table, .. } => table.shared_len(),
        }
    }

    /// Resident bytes this cache holds: buffer *capacity* for the flat
    /// backing (Vec growth doubles — what the allocator actually keeps),
    /// page-granular bytes for the paged backing. Budget and eviction
    /// decisions key on this; the logical size is [`len_bytes`].
    ///
    /// [`len_bytes`]: KvCache::len_bytes
    pub fn byte_size(&self) -> usize {
        match &self.backing {
            KvBacking::Flat(layers) => layers
                .iter()
                .map(|(k, v)| 4 * (k.capacity() + v.capacity()))
                .sum(),
            KvBacking::Paged { pool, table } => pool.held_bytes(table),
        }
    }

    /// Logical bytes of the cached rows: `4 · 2 · n_layers · len · kv_dim`.
    pub fn len_bytes(&self) -> usize {
        4 * 2 * self.n_layers * self.len * self.kv_dim
    }

    /// Reserve room for `extra` more positions — context-cap check, page
    /// allocation, and copy-on-write of shared pages about to be written.
    /// Called before any forward compute; on error the session state is
    /// unchanged.
    fn ensure_capacity(&mut self, extra: usize) -> Result<(), KvError> {
        if self.len.saturating_add(extra) > self.max_len {
            return Err(KvError::ContextOverflow {
                have: self.len,
                extra,
                max: self.max_len,
            });
        }
        match &mut self.backing {
            KvBacking::Flat(_) => Ok(()),
            KvBacking::Paged { pool, table } => pool.ensure(table, self.len, extra),
        }
    }

    /// Adopt the longest registered prefix of `tokens` from the pool's
    /// index (no-op for flat caches / non-empty caches). The adopted rows
    /// are already resident bit-identically; prefill skips storing them.
    pub fn adopt_prefix(&mut self, tokens: &[i32]) -> usize {
        match &mut self.backing {
            KvBacking::Paged { pool, table } if self.len == 0 && table.n_pages() == 0 => {
                pool.adopt(table, tokens)
            }
            _ => 0,
        }
    }

    /// Publish this cache's prompt pages in the pool's prefix index
    /// (no-op for flat caches).
    pub fn register_prefix(&self, tokens: &[i32]) {
        if let KvBacking::Paged { pool, table } = &self.backing {
            debug_assert!(tokens.len() <= self.len, "registering unstored rows");
            pool.register(table, tokens);
        }
    }

    /// Store whole rows (multiples of kv_dim) for one layer at positions
    /// `[len, len + rows)`. Capacity must have been reserved via
    /// [`ensure_capacity`](KvCache::ensure_capacity); `len` advances after
    /// the last layer's rows land.
    fn append(&mut self, layer: usize, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len() % self.kv_dim, 0, "kv row width");
        debug_assert_eq!(k.len(), v.len(), "k/v row count");
        let rows = k.len() / self.kv_dim;
        match &mut self.backing {
            KvBacking::Flat(layers) => {
                layers[layer].0.extend_from_slice(k);
                layers[layer].1.extend_from_slice(v);
            }
            KvBacking::Paged { pool, table } => {
                pool.write_rows(table, layer, self.len, k, v);
            }
        }
        if layer + 1 == self.n_layers {
            self.len += rows;
        }
    }

    /// Roll the cache back to its first `len` positions (speculative-
    /// decode rejection). No-op when already at or below `len`.
    ///
    /// Flat backing just shrinks the row buffers. Paged backing releases
    /// every page wholly past the new length (registered pages stay
    /// cached for prefix sharing) and hardens the boundary page: a shared
    /// (refs > 1) page is left for copy-on-write at the next store, a
    /// privately-held page registered past `len` is deregistered, and the
    /// table's adopted extent is clamped so post-rollback stores are not
    /// skipped. Because K rows are stored post-RoPE at absolute
    /// positions, truncate + re-extend is bit-identical to never having
    /// cached the dropped suffix.
    pub fn truncate(&mut self, len: usize) {
        if len >= self.len {
            return;
        }
        match &mut self.backing {
            KvBacking::Flat(layers) => {
                for (k, v) in layers.iter_mut() {
                    k.truncate(len * self.kv_dim);
                    v.truncate(len * self.kv_dim);
                }
            }
            KvBacking::Paged { pool, table } => pool.truncate(table, len),
        }
        self.len = len;
    }

    /// Copy one kv-head's cached panels over positions `[0, len)`:
    /// (K, V), each (len, head_dim). `len` is explicit because decode
    /// reads a layer's rows after appending them but before the cache
    /// length advances (which happens after the last layer).
    fn head(&self, layer: usize, g: usize, hd: usize, len: usize) -> (Matrix, Matrix) {
        match &self.backing {
            KvBacking::Flat(layers) => {
                let (kbuf, vbuf) = &layers[layer];
                debug_assert!(len * self.kv_dim <= kbuf.len(), "head past stored rows");
                let mut k = Matrix::zeros(len, hd);
                let mut v = Matrix::zeros(len, hd);
                for i in 0..len {
                    let o = i * self.kv_dim + g * hd;
                    k.row_mut(i).copy_from_slice(&kbuf[o..o + hd]);
                    v.row_mut(i).copy_from_slice(&vbuf[o..o + hd]);
                }
                (k, v)
            }
            KvBacking::Paged { pool, table } => pool.read_head(table, layer, g, hd, len),
        }
    }
}

impl Clone for KvCache {
    fn clone(&self) -> KvCache {
        let backing = match &self.backing {
            KvBacking::Flat(layers) => KvBacking::Flat(layers.clone()),
            KvBacking::Paged { pool, table } => KvBacking::Paged {
                pool: pool.clone(),
                table: pool.clone_table(table),
            },
        };
        KvCache {
            kv_dim: self.kv_dim,
            n_layers: self.n_layers,
            len: self.len,
            max_len: self.max_len,
            backing,
        }
    }
}

impl Drop for KvCache {
    fn drop(&mut self) {
        if let KvBacking::Paged { pool, table } = &mut self.backing {
            pool.release(table);
        }
    }
}

#[inline]
fn silu_and_grad(x: f32) -> (f32, f32) {
    let s = 1.0 / (1.0 + (-x).exp());
    (x * s, s * (1.0 + x * (1.0 - s)))
}

#[inline]
fn gelu_and_grad(x: f32) -> (f32, f32) {
    // tanh approximation (jax.nn.gelu default).
    let c = (2.0 / PI).sqrt();
    let u = c * (x + 0.044715 * x * x * x);
    let th = u.tanh();
    let val = 0.5 * x * (1.0 + th);
    let grad = 0.5 * (1.0 + th) + 0.5 * x * (1.0 - th * th) * c * (1.0 + 3.0 * 0.044715 * x * x);
    (val, grad)
}

/// `mid = act(gate) ⊙ up`.
fn glu_mid(gate: &Matrix, up: &Matrix, geglu: bool) -> Matrix {
    let (t, d) = gate.shape();
    let gs = gate.as_slice();
    let us = up.as_slice();
    let mut out = vec![0f32; gs.len()];
    for i in 0..gs.len() {
        let (a, _) = if geglu {
            gelu_and_grad(gs[i])
        } else {
            silu_and_grad(gs[i])
        };
        out[i] = a * us[i];
    }
    Matrix::from_vec(t, d, out)
}

/// Backward of `mid = act(gate) ⊙ up` → (dgate, dup).
fn glu_backward(gate: &Matrix, up: &Matrix, dmid: &Matrix, geglu: bool) -> (Matrix, Matrix) {
    let (t, d) = gate.shape();
    let gs = gate.as_slice();
    let us = up.as_slice();
    let ds = dmid.as_slice();
    let mut dgate = vec![0f32; gs.len()];
    let mut dup = vec![0f32; gs.len()];
    for i in 0..gs.len() {
        let (a, ap) = if geglu {
            gelu_and_grad(gs[i])
        } else {
            silu_and_grad(gs[i])
        };
        dup[i] = ds[i] * a;
        dgate[i] = ds[i] * us[i] * ap;
    }
    (Matrix::from_vec(t, d, dgate), Matrix::from_vec(t, d, dup))
}

/// Causal multi-head attention over flattened (batch·seq, ·) activations.
/// `q` is post-RoPE (batch·seq, d_model); `k`/`v` are post-RoPE/raw
/// (batch·seq, kv_dim). When `save` is provided, the post-softmax attention
/// matrix of each (batch, head) is pushed in order (needed for backward).
fn attention(
    fam: &FamilySpec,
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    batch: usize,
    seq: usize,
    mut save: Option<&mut Vec<Matrix>>,
) -> Matrix {
    let hd = fam.head_dim();
    let nh = fam.n_heads;
    let rep = nh / fam.n_kv_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut ctx = Matrix::zeros(q.rows(), fam.d_model);
    for b in 0..batch {
        let r0 = b * seq;
        let r1 = r0 + seq;
        for h in 0..nh {
            let g = h / rep;
            let qh = q.slice(r0, r1, h * hd, (h + 1) * hd);
            let kh = k.slice(r0, r1, g * hd, (g + 1) * hd);
            let vh = v.slice(r0, r1, g * hd, (g + 1) * hd);
            let mut scores = matmul_nt(&qh, &kh); // (seq, seq)
            for i in 0..seq {
                let row = scores.row_mut(i);
                let mut mx = f32::NEG_INFINITY;
                for cell in row.iter_mut().take(i + 1) {
                    *cell *= scale;
                    mx = mx.max(*cell);
                }
                let mut sum = 0f32;
                for cell in row.iter_mut().take(i + 1) {
                    *cell = (*cell - mx).exp();
                    sum += *cell;
                }
                let inv = 1.0 / sum;
                for cell in row.iter_mut().take(i + 1) {
                    *cell *= inv;
                }
                for cell in row.iter_mut().skip(i + 1) {
                    *cell = 0.0;
                }
            }
            let ctx_h = matmul(&scores, &vh); // (seq, hd)
            for i in 0..seq {
                ctx.row_mut(r0 + i)[h * hd..(h + 1) * hd].copy_from_slice(ctx_h.row(i));
            }
            if let Some(sv) = save.as_mut() {
                sv.push(scores);
            }
        }
    }
    ctx
}

// ---------------------------------------------------------------- forward

/// Dense/compressed transformer forward: `tokens` is a row-major
/// (batch, seq) i32 block; returns logits (batch·seq, vocab). When
/// `capture` is provided, the four calibration activation matrices per
/// layer are appended **untransposed** as (batch·seq, in_dim) — the exec
/// layer transposes them to the artifact's (in_dim, batch·seq) convention.
pub fn forward_with(
    fam: &FamilySpec,
    view: &ParamView,
    proj: &dyn ProjectionOps,
    tokens: &[i32],
    batch: usize,
    seq: usize,
    capture: Option<&mut Vec<Matrix>>,
) -> Result<Matrix> {
    forward_impl(fam, view, proj, tokens, batch, seq, capture, None)
}

#[allow(clippy::too_many_arguments)]
fn forward_impl(
    fam: &FamilySpec,
    view: &ParamView,
    proj: &dyn ProjectionOps,
    tokens: &[i32],
    batch: usize,
    seq: usize,
    mut capture: Option<&mut Vec<Matrix>>,
    mut kv: Option<&mut KvCache>,
) -> Result<Matrix> {
    if tokens.len() != batch * seq {
        bail!("forward expects {}x{} tokens", batch, seq);
    }
    if kv.is_some() && batch != 1 {
        bail!("KV prefill is per-session (batch 1), got batch {batch}");
    }
    let d = fam.d_model;
    let embed = view.get("embed")?;
    let mut x = Matrix::zeros(batch * seq, d);
    for (t, &tok) in tokens.iter().enumerate() {
        let tok = tok as usize;
        if tok >= fam.vocab {
            bail!("token {tok} out of range for vocab {}", fam.vocab);
        }
        x.row_mut(t).copy_from_slice(embed.row(tok));
    }
    let rope = RopeTable::new(seq, fam.head_dim(), fam.rope_theta);
    for layer in 0..fam.n_layers {
        let p = format!("layer{layer}.");
        let g1 = view.get(&format!("{p}ln1"))?;
        let (h, _r1) = rms_norm(&x, g1.as_slice());
        if let Some(cap) = capture.as_mut() {
            cap.push(h.clone()); // attn_in
        }
        let mut q = proj.project(&format!("{p}wq"), &h)?;
        let mut k = proj.project(&format!("{p}wk"), &h)?;
        let v = proj.project(&format!("{p}wv"), &h)?;
        rope.apply(&mut q, seq, false);
        rope.apply(&mut k, seq, false);
        if let Some(cache) = kv.as_deref_mut() {
            // Prefill: stash the exact post-RoPE K / raw V rows the causal
            // attention below consumes, so later decode steps attend over
            // bit-identical history.
            cache.append(layer, k.as_slice(), v.as_slice());
        }
        let ctx = attention(fam, &q, &k, &v, batch, seq, None);
        if let Some(cap) = capture.as_mut() {
            cap.push(ctx.clone()); // attn_ctx
        }
        let attn_out = proj.project(&format!("{p}wo"), &ctx)?;
        x.add_assign(&attn_out);

        let g2 = view.get(&format!("{p}ln2"))?;
        let (h2, _r2) = rms_norm(&x, g2.as_slice());
        if let Some(cap) = capture.as_mut() {
            cap.push(h2.clone()); // mlp_in
        }
        let gate = proj.project(&format!("{p}wgate"), &h2)?;
        let up = proj.project(&format!("{p}wup"), &h2)?;
        let mid = glu_mid(&gate, &up, fam.is_geglu());
        if let Some(cap) = capture.as_mut() {
            cap.push(mid.clone()); // mlp_mid
        }
        let down = proj.project(&format!("{p}wdown"), &mid)?;
        x.add_assign(&down);
    }
    let gf = view.get("ln_f")?;
    let (hf, _rf) = rms_norm(&x, gf.as_slice());
    Ok(matmul_nt(&hf, view.get("unembed")?))
}

/// Session prefill: the ordinary causal forward over a prompt (batch 1)
/// that additionally fills `cache` with each layer's K/V rows. Returns the
/// full (prompt_len, vocab) logits — the caller scores the prompt or
/// samples from the last row. The logits are bit-identical to
/// [`forward_with`] over the same tokens.
pub fn fwd_prefill(
    fam: &FamilySpec,
    view: &ParamView,
    proj: &dyn ProjectionOps,
    tokens: &[i32],
    cache: &mut KvCache,
) -> Result<Matrix> {
    if tokens.is_empty() {
        bail!("prefill needs at least one token");
    }
    if !cache.is_empty() {
        bail!("prefill expects an empty KV cache (got {} cached positions)", cache.len());
    }
    // Reserve every page (and take any needed COW copies) up front: on
    // failure the cache is untouched and the error is typed (context
    // overflow / pool exhausted), never a half-filled prefill.
    cache.ensure_capacity(tokens.len())?;
    forward_impl(fam, view, proj, tokens, 1, tokens.len(), None, Some(cache))
}

/// One prefill chunk: extend a (possibly non-empty) per-session cache by
/// `chunk.len()` consecutive prompt positions and return their logits
/// (chunk_len, vocab). `fwd_prefill` over a prompt equals any sequence of
/// `fwd_prefill_chunk` calls that concatenates to the same prompt,
/// **bit-for-bit**: every per-row operation here is the row-local decode
/// arithmetic of [`fwd_decode`] (RoPE rotated at the row's absolute
/// position, the exact causal-softmax op order, cached-panel attention),
/// which is itself bit-identical to the full forward. The scheduler uses
/// this to interleave long-prompt prefills with decode steps.
///
/// Capacity for the whole chunk is reserved up front; on a typed error the
/// cache is unchanged and the chunk can be retried after preemption.
/// Positions inside an adopted shared prefix are recomputed (logits stay
/// exact) but their stores are skipped — same protocol as one-shot
/// prefill.
pub fn fwd_prefill_chunk(
    fam: &FamilySpec,
    view: &ParamView,
    proj: &dyn ProjectionOps,
    chunk: &[i32],
    cache: &mut KvCache,
) -> Result<Matrix> {
    let m = chunk.len();
    if m == 0 {
        bail!("prefill chunk needs at least one token");
    }
    let pos0 = cache.len();
    cache.ensure_capacity(m)?;
    let d = fam.d_model;
    let embed = view.get("embed")?;
    let mut x = Matrix::zeros(m, d);
    for (r, &tok) in chunk.iter().enumerate() {
        let tok = tok as usize;
        if tok >= fam.vocab {
            bail!("token {tok} out of range for vocab {}", fam.vocab);
        }
        x.row_mut(r).copy_from_slice(embed.row(tok));
    }
    let hd = fam.head_dim();
    let nh = fam.n_heads;
    let rep = nh / fam.n_kv_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    for layer in 0..fam.n_layers {
        let p = format!("layer{layer}.");
        let g1 = view.get(&format!("{p}ln1"))?;
        let (h, _r1) = rms_norm(&x, g1.as_slice());
        let mut q = proj.project(&format!("{p}wq"), &h)?;
        let mut k = proj.project(&format!("{p}wk"), &h)?;
        let v = proj.project(&format!("{p}wv"), &h)?;
        for r in 0..m {
            rope_rotate_row(q.row_mut(r), hd, pos0 + r, fam.rope_theta);
            rope_rotate_row(k.row_mut(r), hd, pos0 + r, fam.rope_theta);
        }
        // Land the whole chunk's K/V rows first (stores below the adopted
        // shared extent are skipped), then attend row by row over the
        // cached history — rows of this chunk included, so intra-chunk
        // causal attention reads the same bits the one-shot path computes.
        cache.append(layer, k.as_slice(), v.as_slice());
        let mut ctx = Matrix::zeros(m, d);
        for r in 0..m {
            let len = pos0 + r + 1;
            for g in 0..fam.n_kv_heads {
                let (kh, vh) = cache.head(layer, g, hd, len);
                debug_assert_eq!(kh.rows(), len, "cache length drift");
                for rr in 0..rep {
                    let hh = g * rep + rr;
                    let qh = q.slice(r, r + 1, hh * hd, (hh + 1) * hd);
                    let mut scores = matmul_nt(&qh, &kh); // (1, len)
                    let row = scores.row_mut(0);
                    let mut mx = f32::NEG_INFINITY;
                    for cell in row.iter_mut().take(len) {
                        *cell *= scale;
                        mx = mx.max(*cell);
                    }
                    let mut sum = 0f32;
                    for cell in row.iter_mut().take(len) {
                        *cell = (*cell - mx).exp();
                        sum += *cell;
                    }
                    let inv = 1.0 / sum;
                    for cell in row.iter_mut().take(len) {
                        *cell *= inv;
                    }
                    let ctx_h = matmul(&scores, &vh); // (1, hd)
                    ctx.row_mut(r)[hh * hd..(hh + 1) * hd].copy_from_slice(ctx_h.row(0));
                }
            }
        }
        let attn_out = proj.project(&format!("{p}wo"), &ctx)?;
        x.add_assign(&attn_out);
        let g2 = view.get(&format!("{p}ln2"))?;
        let (h2, _r2) = rms_norm(&x, g2.as_slice());
        let gate = proj.project(&format!("{p}wgate"), &h2)?;
        let up = proj.project(&format!("{p}wup"), &h2)?;
        let mid = glu_mid(&gate, &up, fam.is_geglu());
        let down = proj.project(&format!("{p}wdown"), &mid)?;
        x.add_assign(&down);
    }
    let gf = view.get("ln_f")?;
    let (hf, _rf) = rms_norm(&x, gf.as_slice());
    Ok(matmul_nt(&hf, view.get("unembed")?))
}

/// Reserve one more position on every cache — the all-or-nothing capacity
/// phase of a decode step, split out so a multi-shard engine can run it
/// across the *whole* batch before dispatching per-shard sub-batches to
/// worker threads. [`fwd_decode`]'s own reservation is idempotent after
/// this (pages exist, COW copies are taken), so a typed failure here
/// leaves every cache untouched and no sub-batch can fail on capacity
/// mid-flight after it succeeds.
pub fn ensure_decode_capacity(caches: &mut [&mut KvCache]) -> Result<()> {
    for cache in caches.iter_mut() {
        cache.ensure_capacity(1)?;
    }
    Ok(())
}

/// One incremental decode step for a batch of sessions: `tokens[i]` is
/// appended to the session behind `caches[i]` and its next-token logits are
/// returned in row `i` of the (n_sessions, vocab) output.
///
/// Sessions may sit at different lengths — each attends over its own cache
/// at its own RoPE offset, so the scheduler can assemble any batch without
/// padding. Per-session results are independent of the batch composition
/// (all cross-row operations are row-local), and bit-identical to the last
/// row of a full-sequence forward over that session's token history.
pub fn fwd_decode(
    fam: &FamilySpec,
    view: &ParamView,
    proj: &dyn ProjectionOps,
    tokens: &[i32],
    caches: &mut [&mut KvCache],
) -> Result<Matrix> {
    let n = tokens.len();
    if n == 0 {
        bail!("decode step needs at least one session");
    }
    if caches.len() != n {
        bail!("decode step: {} tokens for {} sessions", n, caches.len());
    }
    let d = fam.d_model;
    let embed = view.get("embed")?;
    let mut x = Matrix::zeros(n, d);
    let mut positions = Vec::with_capacity(n);
    for (i, &tok) in tokens.iter().enumerate() {
        let tok = tok as usize;
        if tok >= fam.vocab {
            bail!("token {tok} out of range for vocab {}", fam.vocab);
        }
        x.row_mut(i).copy_from_slice(embed.row(tok));
        positions.push(caches[i].len());
    }
    // Reserve one position per session *before* any compute: a context
    // overflow or pool exhaustion surfaces here as a typed error with no
    // cache mutated, so the scheduler can preempt a session and retry the
    // whole step cleanly.
    for cache in caches.iter_mut() {
        cache.ensure_capacity(1)?;
    }
    let hd = fam.head_dim();
    let nh = fam.n_heads;
    let rep = nh / fam.n_kv_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    for layer in 0..fam.n_layers {
        let p = format!("layer{layer}.");
        let g1 = view.get(&format!("{p}ln1"))?;
        let (h, _r1) = rms_norm(&x, g1.as_slice());
        let mut q = proj.project(&format!("{p}wq"), &h)?;
        let mut k = proj.project(&format!("{p}wk"), &h)?;
        let v = proj.project(&format!("{p}wv"), &h)?;
        for i in 0..n {
            rope_rotate_row(q.row_mut(i), hd, positions[i], fam.rope_theta);
            rope_rotate_row(k.row_mut(i), hd, positions[i], fam.rope_theta);
        }
        let mut ctx = Matrix::zeros(n, d);
        for i in 0..n {
            caches[i].append(layer, k.row(i), v.row(i));
            let len = positions[i] + 1;
            // One cached-panel copy per kv group; under GQA all `rep`
            // query heads of the group share it.
            for g in 0..fam.n_kv_heads {
                let (kh, vh) = caches[i].head(layer, g, hd, len);
                debug_assert_eq!(kh.rows(), len, "cache length drift");
                for r in 0..rep {
                    let hh = g * rep + r;
                    let qh = q.slice(i, i + 1, hh * hd, (hh + 1) * hd);
                    let mut scores = matmul_nt(&qh, &kh); // (1, len)
                    // Exact op order of the full-sequence causal softmax
                    // for row i = len-1 (see `attention`): scale+max,
                    // exp+sum, normalize — bit-identical history ⇒
                    // bit-identical row.
                    let row = scores.row_mut(0);
                    let mut mx = f32::NEG_INFINITY;
                    for cell in row.iter_mut().take(len) {
                        *cell *= scale;
                        mx = mx.max(*cell);
                    }
                    let mut sum = 0f32;
                    for cell in row.iter_mut().take(len) {
                        *cell = (*cell - mx).exp();
                        sum += *cell;
                    }
                    let inv = 1.0 / sum;
                    for cell in row.iter_mut().take(len) {
                        *cell *= inv;
                    }
                    let ctx_h = matmul(&scores, &vh); // (1, hd)
                    ctx.row_mut(i)[hh * hd..(hh + 1) * hd].copy_from_slice(ctx_h.row(0));
                }
            }
        }
        let attn_out = proj.project(&format!("{p}wo"), &ctx)?;
        x.add_assign(&attn_out);
        let g2 = view.get(&format!("{p}ln2"))?;
        let (h2, _r2) = rms_norm(&x, g2.as_slice());
        let gate = proj.project(&format!("{p}wgate"), &h2)?;
        let up = proj.project(&format!("{p}wup"), &h2)?;
        let mid = glu_mid(&gate, &up, fam.is_geglu());
        let down = proj.project(&format!("{p}wdown"), &mid)?;
        x.add_assign(&down);
    }
    let gf = view.get("ln_f")?;
    let (hf, _rf) = rms_norm(&x, gf.as_slice());
    Ok(matmul_nt(&hf, view.get("unembed")?))
}

// --------------------------------------------------------------- backward

/// Loss + parameter gradients of one next-token-prediction step.
pub struct TrainStepOut {
    pub loss: f32,
    /// Flat gradients, one per family parameter, in layout order.
    pub grads: Vec<Vec<f32>>,
}

struct LayerTape {
    x_in: Matrix,
    h: Matrix,
    r1: Vec<f32>,
    q: Matrix,
    k: Matrix,
    v: Matrix,
    att: Vec<Matrix>,
    ctx: Matrix,
    x_mid: Matrix,
    h2: Matrix,
    r2: Vec<f32>,
    gate: Matrix,
    up: Matrix,
    mid: Matrix,
}

fn attention_backward(
    fam: &FamilySpec,
    tp: &LayerTape,
    dctx: &Matrix,
    batch: usize,
    seq: usize,
) -> (Matrix, Matrix, Matrix) {
    let hd = fam.head_dim();
    let nh = fam.n_heads;
    let rep = nh / fam.n_kv_heads;
    let kv = fam.kv_dim();
    let scale = 1.0 / (hd as f32).sqrt();
    let t_total = dctx.rows();
    let mut dq = Matrix::zeros(t_total, fam.d_model);
    let mut dk = Matrix::zeros(t_total, kv);
    let mut dv = Matrix::zeros(t_total, kv);
    for b in 0..batch {
        let r0 = b * seq;
        let r1 = r0 + seq;
        for h in 0..nh {
            let a = &tp.att[b * nh + h]; // post-softmax (seq, seq)
            let g = h / rep;
            let qh = tp.q.slice(r0, r1, h * hd, (h + 1) * hd);
            let kh = tp.k.slice(r0, r1, g * hd, (g + 1) * hd);
            let vh = tp.v.slice(r0, r1, g * hd, (g + 1) * hd);
            let dctx_h = dctx.slice(r0, r1, h * hd, (h + 1) * hd);
            let da = matmul_nt(&dctx_h, &vh); // (seq, seq)
            let dvh = matmul_tn(a, &dctx_h); // Aᵀ·dctx → (seq, hd)
            // Softmax backward per causal row; the 1/√hd factor of the
            // score computation is folded in here.
            let mut ds = Matrix::zeros(seq, seq);
            for i in 0..seq {
                let arow = a.row(i);
                let darow = da.row(i);
                let mut dot = 0f32;
                for j in 0..=i {
                    dot += arow[j] * darow[j];
                }
                let dsrow = ds.row_mut(i);
                for j in 0..=i {
                    dsrow[j] = arow[j] * (darow[j] - dot) * scale;
                }
            }
            let dqh = matmul(&ds, &kh); // (seq, hd)
            let dkh = matmul_tn(&ds, &qh); // dSᵀ·Q → (seq, hd)
            for i in 0..seq {
                dq.row_mut(r0 + i)[h * hd..(h + 1) * hd].copy_from_slice(dqh.row(i));
                // kv heads are shared across `rep` query heads: accumulate.
                let dst = &mut dk.row_mut(r0 + i)[g * hd..(g + 1) * hd];
                for (o, s0) in dst.iter_mut().zip(dkh.row(i)) {
                    *o += *s0;
                }
                let dst = &mut dv.row_mut(r0 + i)[g * hd..(g + 1) * hd];
                for (o, s0) in dst.iter_mut().zip(dvh.row(i)) {
                    *o += *s0;
                }
            }
        }
    }
    (dq, dk, dv)
}

/// Next-token cross-entropy loss and full gradients for a (batch, seq+1)
/// token block — the reverse-mode mirror of `model.loss_fn`.
pub fn loss_and_grads(
    fam: &FamilySpec,
    view: &ParamView,
    tokens: &[i32],
    batch: usize,
    seq_plus1: usize,
) -> Result<TrainStepOut> {
    if tokens.len() != batch * seq_plus1 {
        bail!("train expects {}x{} tokens", batch, seq_plus1);
    }
    let s = seq_plus1 - 1;
    let t_total = batch * s;
    let d = fam.d_model;

    let mut inp = vec![0i32; t_total];
    let mut tgt = vec![0usize; t_total];
    for b in 0..batch {
        for t in 0..s {
            inp[b * s + t] = tokens[b * seq_plus1 + t];
            tgt[b * s + t] = tokens[b * seq_plus1 + t + 1] as usize;
        }
    }

    // ---- forward with tape ----
    let embed = view.get("embed")?;
    let mut x = Matrix::zeros(t_total, d);
    for (i, &tok) in inp.iter().enumerate() {
        let tok = tok as usize;
        if tok >= fam.vocab {
            bail!("token {tok} out of range for vocab {}", fam.vocab);
        }
        x.row_mut(i).copy_from_slice(embed.row(tok));
    }
    for &t in &tgt {
        if t >= fam.vocab {
            bail!("target token {t} out of range");
        }
    }
    let rope = RopeTable::new(s, fam.head_dim(), fam.rope_theta);
    let geglu = fam.is_geglu();
    let mut tapes: Vec<LayerTape> = Vec::with_capacity(fam.n_layers);
    for layer in 0..fam.n_layers {
        let p = format!("layer{layer}.");
        let x_in = x.clone();
        let g1 = view.get(&format!("{p}ln1"))?;
        let (h, r1) = rms_norm(&x, g1.as_slice());
        let mut q = matmul_nt(&h, view.get(&format!("{p}wq"))?);
        let mut k = matmul_nt(&h, view.get(&format!("{p}wk"))?);
        let v = matmul_nt(&h, view.get(&format!("{p}wv"))?);
        rope.apply(&mut q, s, false);
        rope.apply(&mut k, s, false);
        let mut att = Vec::with_capacity(batch * fam.n_heads);
        let ctx = attention(fam, &q, &k, &v, batch, s, Some(&mut att));
        let attn_out = matmul_nt(&ctx, view.get(&format!("{p}wo"))?);
        x.add_assign(&attn_out);
        let x_mid = x.clone();
        let g2 = view.get(&format!("{p}ln2"))?;
        let (h2, r2) = rms_norm(&x, g2.as_slice());
        let gate = matmul_nt(&h2, view.get(&format!("{p}wgate"))?);
        let up = matmul_nt(&h2, view.get(&format!("{p}wup"))?);
        let mid = glu_mid(&gate, &up, geglu);
        let down = matmul_nt(&mid, view.get(&format!("{p}wdown"))?);
        x.add_assign(&down);
        tapes.push(LayerTape {
            x_in,
            h,
            r1,
            q,
            k,
            v,
            att,
            ctx,
            x_mid,
            h2,
            r2,
            gate,
            up,
            mid,
        });
    }
    let x_final = x;
    let gf = view.get("ln_f")?;
    let (hf, rf) = rms_norm(&x_final, gf.as_slice());
    let unembed = view.get("unembed")?;
    let logits = matmul_nt(&hf, unembed);

    // ---- loss + dlogits ----
    let vocab = fam.vocab;
    let mut dlogits = Matrix::zeros(t_total, vocab);
    let mut nll_sum = 0f64;
    let invn = 1.0 / t_total as f32;
    for i in 0..t_total {
        let row = logits.row(i);
        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
        let mut sum = 0f64;
        for &v in row {
            sum += ((v as f64) - mx).exp();
        }
        let lse = sum.ln() + mx;
        nll_sum += lse - row[tgt[i]] as f64;
        let drow = dlogits.row_mut(i);
        for j in 0..vocab {
            drow[j] = (((row[j] as f64) - lse).exp() as f32) * invn;
        }
        drow[tgt[i]] -= invn;
    }
    let loss = (nll_sum / t_total as f64) as f32;

    // ---- backward ----
    let mut grads: Vec<Vec<f32>> = fam
        .params
        .iter()
        .map(|(_, sh)| vec![0f32; sh.iter().product()])
        .collect();
    let acc_mat = |grads: &mut Vec<Vec<f32>>, name: &str, m: &Matrix| -> Result<()> {
        let idx = fam.param_index(name)?;
        let dst = &mut grads[idx];
        debug_assert_eq!(dst.len(), m.as_slice().len(), "grad shape for {name}");
        for (o, &v) in dst.iter_mut().zip(m.as_slice()) {
            *o += v;
        }
        Ok(())
    };
    let acc_vec = |grads: &mut Vec<Vec<f32>>, name: &str, v: &[f32]| -> Result<()> {
        let idx = fam.param_index(name)?;
        let dst = &mut grads[idx];
        debug_assert_eq!(dst.len(), v.len(), "grad shape for {name}");
        for (o, &x) in dst.iter_mut().zip(v) {
            *o += x;
        }
        Ok(())
    };

    acc_mat(&mut grads, "unembed", &matmul_tn(&dlogits, &hf))?;
    let dhf = matmul(&dlogits, unembed);
    let (mut dx, dgf) = rms_backward(&x_final, gf.as_slice(), &rf, &dhf);
    acc_vec(&mut grads, "ln_f", &dgf)?;

    for layer in (0..fam.n_layers).rev() {
        let p = format!("layer{layer}.");
        let tp = &tapes[layer];
        // MLP block: x_out = x_mid + mid·Wdᵀ
        let wdown = view.get(&format!("{p}wdown"))?;
        acc_mat(&mut grads, &format!("{p}wdown"), &matmul_tn(&dx, &tp.mid))?;
        let dmid = matmul(&dx, wdown);
        let (dgate, dup) = glu_backward(&tp.gate, &tp.up, &dmid, geglu);
        acc_mat(&mut grads, &format!("{p}wgate"), &matmul_tn(&dgate, &tp.h2))?;
        acc_mat(&mut grads, &format!("{p}wup"), &matmul_tn(&dup, &tp.h2))?;
        let mut dh2 = matmul(&dgate, view.get(&format!("{p}wgate"))?);
        dh2.add_assign(&matmul(&dup, view.get(&format!("{p}wup"))?));
        let g2 = view.get(&format!("{p}ln2"))?;
        let (dxm_norm, dg2) = rms_backward(&tp.x_mid, g2.as_slice(), &tp.r2, &dh2);
        acc_vec(&mut grads, &format!("{p}ln2"), &dg2)?;
        let mut dx_mid = dx;
        dx_mid.add_assign(&dxm_norm);

        // Attention block: x_mid = x_in + ctx·Woᵀ
        let wo = view.get(&format!("{p}wo"))?;
        acc_mat(&mut grads, &format!("{p}wo"), &matmul_tn(&dx_mid, &tp.ctx))?;
        let dctx = matmul(&dx_mid, wo);
        let (mut dq, mut dk, dv) = attention_backward(fam, tp, &dctx, batch, s);
        rope.apply(&mut dq, s, true);
        rope.apply(&mut dk, s, true);
        acc_mat(&mut grads, &format!("{p}wq"), &matmul_tn(&dq, &tp.h))?;
        acc_mat(&mut grads, &format!("{p}wk"), &matmul_tn(&dk, &tp.h))?;
        acc_mat(&mut grads, &format!("{p}wv"), &matmul_tn(&dv, &tp.h))?;
        let mut dh = matmul(&dq, view.get(&format!("{p}wq"))?);
        dh.add_assign(&matmul(&dk, view.get(&format!("{p}wk"))?));
        dh.add_assign(&matmul(&dv, view.get(&format!("{p}wv"))?));
        let g1 = view.get(&format!("{p}ln1"))?;
        let (dxin_norm, dg1) = rms_backward(&tp.x_in, g1.as_slice(), &tp.r1, &dh);
        acc_vec(&mut grads, &format!("{p}ln1"), &dg1)?;
        dx = dx_mid;
        dx.add_assign(&dxin_norm);
    }

    // Embedding gradient: scatter-add token rows.
    let embed_idx = fam.param_index("embed")?;
    for (i, &tok) in inp.iter().enumerate() {
        let base = (tok as usize) * d;
        let row = dx.row(i);
        let eg = &mut grads[embed_idx];
        for j in 0..d {
            eg[base + j] += row[j];
        }
    }

    Ok(TrainStepOut { loss, grads })
}

// ----------------------------------------------------------------- adamw

const ADAM_LR: f32 = 3e-3;
const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.95;
const ADAM_EPS: f32 = 1e-8;
const ADAM_WD: f32 = 0.01;

/// One AdamW update mirroring `model.train_step` exactly (`t = step+1`,
/// bias-corrected moments, decoupled weight decay skipped on norms).
pub(crate) fn adamw_update(
    p: &[f32],
    m: &[f32],
    v: &[f32],
    g: &[f32],
    step: f32,
    decay: f32,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let t = step + 1.0;
    let bc1 = 1.0 - ADAM_B1.powf(t);
    let bc2 = 1.0 - ADAM_B2.powf(t);
    let mut np = Vec::with_capacity(p.len());
    let mut nm = Vec::with_capacity(p.len());
    let mut nv = Vec::with_capacity(p.len());
    for j in 0..p.len() {
        let m2 = ADAM_B1 * m[j] + (1.0 - ADAM_B1) * g[j];
        let v2 = ADAM_B2 * v[j] + (1.0 - ADAM_B2) * g[j] * g[j];
        let upd = (m2 / bc1) / ((v2 / bc2).sqrt() + ADAM_EPS);
        np.push(p[j] - ADAM_LR * (upd + decay * p[j]));
        nm.push(m2);
        nv.push(v2);
    }
    (np, nm, nv)
}

// ------------------------------------------------------------------ exec

/// Execute an artifact natively. Inputs are already validated against the
/// manifest by [`super::Runtime::exec`].
pub fn exec(manifest: &Manifest, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
    // Standalone kernels (identical semantics to the Pallas lowerings).
    match name {
        "kernel_quantize" => {
            let w = inputs[0].to_matrix()?;
            let deq = UniformQuantizer::new(4, 32).quantize(&w).deq;
            return Ok(vec![Value::from_matrix(&deq)]);
        }
        "kernel_fused_qlr" => {
            let q = inputs[0].to_matrix()?;
            let l = inputs[1].to_matrix()?;
            let r = inputs[2].to_matrix()?;
            let x = inputs[3].to_matrix()?;
            let y = crate::fused::qlr_matmul(&q, &l, &r, &x);
            return Ok(vec![Value::from_matrix(&y)]);
        }
        "kernel_fwht" => {
            let mut w = inputs[0].to_matrix()?;
            crate::hadamard::fwht_rows(&mut w);
            return Ok(vec![Value::from_matrix(&w)]);
        }
        _ => {}
    }
    let (batch, seq) = (manifest.batch, manifest.seq);
    if let Some(fam_name) = name.strip_prefix("fwd_fused_") {
        let fam = manifest.family(fam_name)?;
        let n = fam.params.len();
        let view = ParamView::from_values(fam, &inputs[..n])?;
        let mut mats = BTreeMap::new();
        let mut off = n;
        for proj in &fam.projections {
            let q = inputs[off].to_matrix()?;
            let l = inputs[off + 1].to_matrix()?;
            let r = inputs[off + 2].to_matrix()?;
            mats.insert(proj.clone(), (q, l, r));
            off += 3;
        }
        let tokens = inputs[off].i32_data()?;
        let provider = QlrDenseProj { mats };
        let logits = forward_with(fam, &view, &provider, tokens, batch, seq, None)?;
        return Ok(vec![Value::F32 {
            shape: vec![batch, seq, fam.vocab],
            data: logits.into_vec(),
        }]);
    }
    if let Some(fam_name) = name.strip_prefix("fwd_") {
        let fam = manifest.family(fam_name)?;
        let n = fam.params.len();
        let view = ParamView::from_values(fam, &inputs[..n])?;
        let tokens = inputs[n].i32_data()?;
        let provider = DenseProj { view: &view };
        let logits = forward_with(fam, &view, &provider, tokens, batch, seq, None)?;
        return Ok(vec![Value::F32 {
            shape: vec![batch, seq, fam.vocab],
            data: logits.into_vec(),
        }]);
    }
    if let Some(fam_name) = name.strip_prefix("capture_") {
        let fam = manifest.family(fam_name)?;
        let n = fam.params.len();
        let view = ParamView::from_values(fam, &inputs[..n])?;
        let tokens = inputs[n].i32_data()?;
        let provider = DenseProj { view: &view };
        let mut caps: Vec<Matrix> = Vec::with_capacity(4 * fam.n_layers);
        forward_with(fam, &view, &provider, tokens, batch, seq, Some(&mut caps))?;
        return Ok(caps
            .into_iter()
            .map(|m| {
                let t = m.transpose(); // (in_dim, batch·seq), columns = samples
                Value::F32 {
                    shape: vec![t.rows(), t.cols()],
                    data: t.into_vec(),
                }
            })
            .collect());
    }
    if let Some(fam_name) = name.strip_prefix("train_") {
        let fam = manifest.family(fam_name)?;
        let n = fam.params.len();
        let view = ParamView::from_values(fam, &inputs[..n])?;
        let m_in = &inputs[n..2 * n];
        let v_in = &inputs[2 * n..3 * n];
        let step = inputs[3 * n].f32_data()?[0];
        let tokens = inputs[3 * n + 1].i32_data()?;
        let out = loss_and_grads(fam, &view, tokens, batch, seq + 1)?;
        let mut new_p = Vec::with_capacity(n);
        let mut new_m = Vec::with_capacity(n);
        let mut new_v = Vec::with_capacity(n);
        for (i, (pname, shape)) in fam.params.iter().enumerate() {
            let decay = if FamilySpec::is_norm(pname) {
                0.0
            } else {
                ADAM_WD
            };
            let (np, nm, nv) = adamw_update(
                inputs[i].f32_data()?,
                m_in[i].f32_data()?,
                v_in[i].f32_data()?,
                &out.grads[i],
                step,
                decay,
            );
            new_p.push(Value::from_vec_f32(shape.clone(), np));
            new_m.push(Value::from_vec_f32(shape.clone(), nm));
            new_v.push(Value::from_vec_f32(shape.clone(), nv));
        }
        let mut outs = new_p;
        outs.extend(new_m);
        outs.extend(new_v);
        outs.push(Value::scalar_f32(out.loss));
        return Ok(outs);
    }
    bail!("artifact '{name}' has no native implementation")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn micro_family() -> FamilySpec {
        // GQA (2 query heads sharing 1 kv head) + SwiGLU, small enough for
        // finite differences.
        FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu")
    }

    fn micro_tokens(fam: &FamilySpec, batch: usize, len: usize, seed: u64) -> Vec<i32> {
        let mut rng = Pcg64::new(seed, 77);
        (0..batch * len)
            .map(|_| rng.below(fam.vocab) as i32)
            .collect()
    }

    #[test]
    fn rope_inverse_roundtrips() {
        let mut rng = Pcg64::new(1, 1);
        let mut m = Matrix::randn(12, 8, 1.0, &mut rng);
        let orig = m.clone();
        let rope = RopeTable::new(4, 4, 10000.0);
        rope.apply(&mut m, 4, false);
        assert!(m.max_abs_diff(&orig) > 1e-3, "rope must rotate something");
        rope.apply(&mut m, 4, true);
        assert!(m.max_abs_diff(&orig) < 1e-5);
    }

    #[test]
    fn rope_rotate_row_matches_table_bit_exactly() {
        // The decode path computes table entries on the fly; its arithmetic
        // must reproduce RopeTable::apply exactly or the bit-identity
        // contract between decode and the full forward breaks.
        let mut rng = Pcg64::new(6, 1);
        let (seq, hd) = (6usize, 4usize);
        let mut via_table = Matrix::randn(seq, 2 * hd, 1.0, &mut rng); // 2 heads
        let mut via_row = via_table.clone();
        let rope = RopeTable::new(seq, hd, 10000.0);
        rope.apply(&mut via_table, seq, false);
        for i in 0..seq {
            rope_rotate_row(via_row.row_mut(i), hd, i, 10000.0);
        }
        assert_eq!(via_table.max_abs_diff(&via_row), 0.0);
    }

    #[test]
    fn rms_norm_unit_rows() {
        // With g = 1 the output rows have RMS ≈ 1.
        let mut rng = Pcg64::new(2, 1);
        let x = Matrix::randn(5, 16, 3.0, &mut rng);
        let g = vec![1.0f32; 16];
        let (y, rs) = rms_norm(&x, &g);
        for i in 0..5 {
            let ms: f32 = y.row(i).iter().map(|v| v * v).sum::<f32>() / 16.0;
            assert!((ms - 1.0).abs() < 1e-3, "row {i}: ms={ms}");
            assert!(rs[i] > 0.0);
        }
    }

    #[test]
    fn attention_rows_are_convex_combinations() {
        let fam = micro_family();
        let mut rng = Pcg64::new(3, 1);
        let (b, s) = (2usize, 5usize);
        let q = Matrix::randn(b * s, fam.d_model, 1.0, &mut rng);
        let k = Matrix::randn(b * s, fam.kv_dim(), 1.0, &mut rng);
        let v = Matrix::randn(b * s, fam.kv_dim(), 1.0, &mut rng);
        let mut att = Vec::new();
        let ctx = attention(&fam, &q, &k, &v, b, s, Some(&mut att));
        assert_eq!(ctx.shape(), (b * s, fam.d_model));
        assert_eq!(att.len(), b * fam.n_heads);
        for a in &att {
            for i in 0..s {
                let row = a.row(i);
                let sum: f32 = row.iter().sum();
                assert!((sum - 1.0).abs() < 1e-4, "softmax row sums to {sum}");
                // Causal: nothing attends to the future.
                for j in i + 1..s {
                    assert_eq!(row[j], 0.0);
                }
            }
        }
        // Position 0 attends only to itself: ctx row 0 = v row 0 per head.
        let hd = fam.head_dim();
        for h in 0..fam.n_heads {
            let g = h / (fam.n_heads / fam.n_kv_heads);
            for j in 0..hd {
                let got = ctx.at(0, h * hd + j);
                let want = v.at(0, g * hd + j);
                assert!((got - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let fam = micro_family();
        let params = ModelParams::init(&fam, 5);
        let view = ParamView::from_params(&params).unwrap();
        let proj = DenseProj { view: &view };
        let (b, s) = (2usize, 6usize);
        let tokens = micro_tokens(&fam, b, s, 1);
        let mut caps = Vec::new();
        let logits =
            forward_with(&fam, &view, &proj, &tokens, b, s, Some(&mut caps)).unwrap();
        assert_eq!(logits.shape(), (b * s, fam.vocab));
        assert!(logits.is_finite());
        assert_eq!(caps.len(), 4 * fam.n_layers);
        assert_eq!(caps[0].shape(), (b * s, fam.d_model));
        assert_eq!(caps[3].shape(), (b * s, fam.d_ff));
    }

    #[test]
    fn fused_provider_matches_dense_forward() {
        // Q = W − L·R with random small factors ⇒ identical logits.
        let fam = micro_family();
        let params = ModelParams::init(&fam, 6);
        let view = ParamView::from_params(&params).unwrap();
        let mut rng = Pcg64::new(7, 7);
        let rank = 3;
        let mut mats = BTreeMap::new();
        for proj in &fam.projections {
            let w = params.get_matrix(proj).unwrap();
            let l = Matrix::randn(w.rows(), rank, 0.1, &mut rng);
            let r = Matrix::randn(rank, w.cols(), 0.1, &mut rng);
            let q = w.sub(&l.dot(&r));
            mats.insert(proj.clone(), (q, l, r));
        }
        let (b, s) = (2usize, 6usize);
        let tokens = micro_tokens(&fam, b, s, 2);
        let dense = forward_with(
            &fam,
            &view,
            &DenseProj { view: &view },
            &tokens,
            b,
            s,
            None,
        )
        .unwrap();
        let fused =
            forward_with(&fam, &view, &QlrDenseProj { mats }, &tokens, b, s, None).unwrap();
        assert!(
            fused.rel_err(&dense) < 1e-4,
            "fused vs dense rel err {}",
            fused.rel_err(&dense)
        );
    }

    #[test]
    fn prefill_logits_match_full_forward_bit_exactly() {
        let fam = micro_family();
        let params = ModelParams::init(&fam, 31);
        let view = ParamView::from_params(&params).unwrap();
        let proj = DenseProj { view: &view };
        let tokens = micro_tokens(&fam, 1, 7, 9);
        let full = forward_with(&fam, &view, &proj, &tokens, 1, 7, None).unwrap();
        let mut cache = KvCache::for_family(&fam);
        let pre = fwd_prefill(&fam, &view, &proj, &tokens, &mut cache).unwrap();
        assert_eq!(pre.shape(), full.shape());
        assert_eq!(pre.max_abs_diff(&full), 0.0, "prefill diverged from forward");
        assert_eq!(cache.len(), 7);
        assert!(cache.byte_size() > 0);
        // Prefill refuses a dirty cache.
        assert!(fwd_prefill(&fam, &view, &proj, &tokens, &mut cache).is_err());
    }

    #[test]
    fn chunked_prefill_is_bit_identical_to_one_shot() {
        // Any chunking of a prompt (page-aligned or ragged) must produce
        // the same per-row logits, the same cache contents, and the same
        // subsequent decode steps as one-shot prefill — on both backings.
        let fam = micro_family();
        let params = ModelParams::init(&fam, 44);
        let view = ParamView::from_params(&params).unwrap();
        let proj = DenseProj { view: &view };
        let tokens = micro_tokens(&fam, 1, 10, 17);
        let mut oneshot = KvCache::for_family(&fam);
        let full = fwd_prefill(&fam, &view, &proj, &tokens, &mut oneshot).unwrap();
        let pool = KvPool::new(fam.n_layers, fam.kv_dim(), 4, 64 * 1024).unwrap();
        for split in [vec![4usize, 4, 2], vec![3, 3, 3, 1], vec![10], vec![1; 10]] {
            let mut flat = KvCache::for_family(&fam);
            let mut paged = KvCache::paged(&pool, 64);
            for cache in [&mut flat, &mut paged] {
                let mut pos = 0usize;
                for &m in &split {
                    let logits =
                        fwd_prefill_chunk(&fam, &view, &proj, &tokens[pos..pos + m], cache)
                            .unwrap();
                    assert_eq!(logits.shape(), (m, fam.vocab));
                    for r in 0..m {
                        for j in 0..fam.vocab {
                            assert_eq!(
                                logits.at(r, j),
                                full.at(pos + r, j),
                                "split {split:?} pos {} col {j}",
                                pos + r
                            );
                        }
                    }
                    pos += m;
                }
                assert_eq!(cache.len(), tokens.len());
            }
            // The caches are interchangeable with the one-shot one: the
            // next decode step agrees bit-for-bit.
            let want = {
                let mut solo = oneshot.clone();
                let mut caches = [&mut solo];
                fwd_decode(&fam, &view, &proj, &[5], &mut caches).unwrap()
            };
            let got = {
                let mut caches = [&mut flat, &mut paged];
                fwd_decode(&fam, &view, &proj, &[5, 5], &mut caches).unwrap()
            };
            for j in 0..fam.vocab {
                assert_eq!(got.at(0, j), want.at(0, j), "flat decode col {j}");
                assert_eq!(got.at(1, j), want.at(0, j), "paged decode col {j}");
            }
        }
        // Chunk growth past the cap is typed and leaves the cache intact.
        let mut capped = KvCache::for_family(&fam).with_max_len(5);
        fwd_prefill_chunk(&fam, &view, &proj, &tokens[..4], &mut capped).unwrap();
        let err =
            fwd_prefill_chunk(&fam, &view, &proj, &tokens[4..8], &mut capped).unwrap_err();
        assert!(KvError::is_context_overflow(&err), "got: {err:#}");
        assert_eq!(capped.len(), 4, "failed chunk dirtied the cache");
    }

    #[test]
    fn incremental_decode_is_bit_identical_to_full_forward() {
        // Prefill a prompt, then feed tokens one at a time: at every step
        // the decode logits must equal the last row of a full-sequence
        // forward over the same history, bit-for-bit (GQA family).
        let fam = micro_family();
        let params = ModelParams::init(&fam, 32);
        let view = ParamView::from_params(&params).unwrap();
        let proj = DenseProj { view: &view };
        let tokens = micro_tokens(&fam, 1, 10, 11);
        let prompt_len = 4usize;
        let mut cache = KvCache::for_family(&fam);
        fwd_prefill(&fam, &view, &proj, &tokens[..prompt_len], &mut cache).unwrap();
        for t in prompt_len..tokens.len() {
            let mut caches = [&mut cache];
            let step =
                fwd_decode(&fam, &view, &proj, &tokens[t..t + 1], &mut caches).unwrap();
            let full =
                forward_with(&fam, &view, &proj, &tokens[..t + 1], 1, t + 1, None).unwrap();
            assert_eq!(step.shape(), (1, fam.vocab));
            let mut max_diff = 0f32;
            for j in 0..fam.vocab {
                max_diff = max_diff.max((step.at(0, j) - full.at(t, j)).abs());
            }
            assert_eq!(max_diff, 0.0, "decode step {t} diverged from full forward");
        }
        assert_eq!(cache.len(), tokens.len());
    }

    #[test]
    fn batched_decode_matches_solo_decode_per_session() {
        // Sessions at different lengths decoded in one batch must produce
        // exactly the logits each would produce decoded alone — the
        // invariant continuous batching relies on. GeGLU family for MLP
        // coverage.
        let fam = FamilySpec::build("micro-g", 13, 8, 2, 2, 1, 10, "geglu");
        let params = ModelParams::init(&fam, 33);
        let view = ParamView::from_params(&params).unwrap();
        let proj = DenseProj { view: &view };
        let a_toks = micro_tokens(&fam, 1, 6, 21);
        let b_toks = micro_tokens(&fam, 1, 3, 22);
        let mut a_solo = KvCache::for_family(&fam);
        let mut b_solo = KvCache::for_family(&fam);
        fwd_prefill(&fam, &view, &proj, &a_toks, &mut a_solo).unwrap();
        fwd_prefill(&fam, &view, &proj, &b_toks, &mut b_solo).unwrap();
        let mut a_bat = a_solo.clone();
        let mut b_bat = b_solo.clone();
        let next = [1i32, 2];
        let solo_a = {
            let mut caches = [&mut a_solo];
            fwd_decode(&fam, &view, &proj, &next[..1], &mut caches).unwrap()
        };
        let solo_b = {
            let mut caches = [&mut b_solo];
            fwd_decode(&fam, &view, &proj, &next[1..], &mut caches).unwrap()
        };
        let both = {
            let mut caches = [&mut a_bat, &mut b_bat];
            fwd_decode(&fam, &view, &proj, &next, &mut caches).unwrap()
        };
        assert_eq!(both.shape(), (2, fam.vocab));
        for j in 0..fam.vocab {
            assert_eq!(both.at(0, j), solo_a.at(0, j), "session A col {j}");
            assert_eq!(both.at(1, j), solo_b.at(0, j), "session B col {j}");
        }
        assert_eq!(a_bat.len(), 7);
        assert_eq!(b_bat.len(), 4);
    }

    #[test]
    fn paged_cache_decodes_bit_identically_to_flat() {
        // Same prompt, same decode steps, one session on flat buffers and
        // one on a paged pool with a page smaller than the prompt: every
        // step's logits must agree bit-for-bit across page boundaries and
        // the COW/adoption machinery.
        let fam = micro_family();
        let params = ModelParams::init(&fam, 41);
        let view = ParamView::from_params(&params).unwrap();
        let proj = DenseProj { view: &view };
        let tokens = micro_tokens(&fam, 1, 10, 13);
        let prompt_len = 6usize;
        let pool = KvPool::new(fam.n_layers, fam.kv_dim(), 4, 64 * 1024).unwrap();
        let mut flat = KvCache::for_family(&fam);
        let mut paged = KvCache::paged(&pool, 64);
        let a = fwd_prefill(&fam, &view, &proj, &tokens[..prompt_len], &mut flat).unwrap();
        let b = fwd_prefill(&fam, &view, &proj, &tokens[..prompt_len], &mut paged).unwrap();
        assert_eq!(a.max_abs_diff(&b), 0.0, "paged prefill diverged");
        paged.register_prefix(&tokens[..prompt_len]);
        // A second paged session adopting the prompt chain must also match.
        let mut shared = KvCache::paged(&pool, 64);
        assert_eq!(shared.adopt_prefix(&tokens[..prompt_len]), prompt_len);
        let c = fwd_prefill(&fam, &view, &proj, &tokens[..prompt_len], &mut shared).unwrap();
        assert_eq!(a.max_abs_diff(&c), 0.0, "adopted prefill diverged");
        for t in prompt_len..tokens.len() {
            let sa = {
                let mut caches = [&mut flat];
                fwd_decode(&fam, &view, &proj, &tokens[t..t + 1], &mut caches).unwrap()
            };
            let sb = {
                let mut caches = [&mut paged, &mut shared];
                let two = [tokens[t], tokens[t]];
                fwd_decode(&fam, &view, &proj, &two, &mut caches).unwrap()
            };
            for j in 0..fam.vocab {
                assert_eq!(sb.at(0, j), sa.at(0, j), "paged step {t} col {j}");
                assert_eq!(sb.at(1, j), sa.at(0, j), "shared step {t} col {j}");
            }
        }
        assert_eq!(paged.len(), tokens.len());
        let stats = pool.stats();
        assert!(stats.shared_adoptions >= 2, "prefix sharing never engaged");
        assert!(stats.cow_copies >= 1, "divergence never took a COW copy");
        assert!(stats.resident_pages <= stats.max_pages);
    }

    #[test]
    fn truncate_then_reextend_is_bit_identical_on_both_backings() {
        // Speculative decoding's rollback contract: truncate(len), then
        // re-extending the stream, must behave exactly as if the dropped
        // suffix had never been cached — on flat buffers, on paged
        // tables, and on a paged table rolled back *into* its adopted
        // extent. K rows are stored post-RoPE at absolute positions, so
        // this holds bit-for-bit.
        let fam = micro_family();
        let params = ModelParams::init(&fam, 45);
        let view = ParamView::from_params(&params).unwrap();
        let proj = DenseProj { view: &view };
        let tokens = micro_tokens(&fam, 1, 12, 19);
        let want = |t: usize| {
            let full =
                forward_with(&fam, &view, &proj, &tokens[..t + 1], 1, t + 1, None).unwrap();
            (0..fam.vocab).map(|j| full.at(t, j)).collect::<Vec<f32>>()
        };
        let pool = KvPool::new(fam.n_layers, fam.kv_dim(), 4, 64 * 1024).unwrap();
        let mut flat = KvCache::for_family(&fam);
        let mut paged = KvCache::paged(&pool, 64);
        let mut donor = KvCache::paged(&pool, 64);
        fwd_prefill(&fam, &view, &proj, &tokens[..6], &mut donor).unwrap();
        donor.register_prefix(&tokens[..6]);
        let mut adopted = KvCache::paged(&pool, 64);
        assert_eq!(adopted.adopt_prefix(&tokens[..6]), 6);
        for cache in [&mut flat, &mut paged, &mut adopted] {
            fwd_prefill(&fam, &view, &proj, &tokens[..6], &mut *cache).unwrap();
            // A rejected speculation: three wrong tokens land in the
            // cache, then the whole excursion is rolled back past the
            // prompt boundary (into the adopted extent for `adopted`).
            for &g in &[2i32, 4, 6] {
                let mut caches = [&mut *cache];
                fwd_decode(&fam, &view, &proj, &[g], &mut caches).unwrap();
            }
            assert_eq!(cache.len(), 9);
            cache.truncate(5);
            assert_eq!(cache.len(), 5);
            // Re-extending along the real stream matches the
            // never-rolled-back reference at every step.
            for t in 5..tokens.len() {
                let step = {
                    let mut caches = [&mut *cache];
                    fwd_decode(&fam, &view, &proj, &tokens[t..t + 1], &mut caches).unwrap()
                };
                assert_eq!(step.row(0), &want(t)[..], "step {t} diverged after rollback");
            }
            assert_eq!(cache.len(), tokens.len());
        }
        // The donor's registered prompt survived its adopter's rollback.
        let mut fresh = KvCache::paged(&pool, 64);
        assert_eq!(fresh.adopt_prefix(&tokens[..6]), 6);
    }

    #[test]
    fn growth_past_the_cap_is_a_typed_context_overflow() {
        // Satellite regression: the cache used to grow unbounded past the
        // engine's validated sequence length. Both prefill and decode must
        // refuse with a typed error, leaving the cache untouched.
        let fam = micro_family();
        let params = ModelParams::init(&fam, 42);
        let view = ParamView::from_params(&params).unwrap();
        let proj = DenseProj { view: &view };
        let tokens = micro_tokens(&fam, 1, 6, 14);
        let mut cache = KvCache::for_family(&fam).with_max_len(5);
        let err = fwd_prefill(&fam, &view, &proj, &tokens, &mut cache).unwrap_err();
        assert!(KvError::is_context_overflow(&err), "got: {err:#}");
        assert!(cache.is_empty(), "failed prefill dirtied the cache");
        fwd_prefill(&fam, &view, &proj, &tokens[..4], &mut cache).unwrap();
        {
            let mut caches = [&mut cache];
            fwd_decode(&fam, &view, &proj, &tokens[4..5], &mut caches).unwrap();
        }
        assert_eq!(cache.len(), 5);
        let mut caches = [&mut cache];
        let err = fwd_decode(&fam, &view, &proj, &tokens[5..6], &mut caches).unwrap_err();
        assert!(KvError::is_context_overflow(&err), "got: {err:#}");
        assert_eq!(cache.len(), 5, "failed decode appended rows");
    }

    #[test]
    fn byte_size_reports_capacity_and_len_bytes_logical() {
        // Satellite regression: byte_size() used to report len-based bytes
        // while Vec doubling keeps more resident — budget decisions keyed
        // on it undercounted. Capacity is what is resident.
        let fam = micro_family();
        let params = ModelParams::init(&fam, 43);
        let view = ParamView::from_params(&params).unwrap();
        let proj = DenseProj { view: &view };
        let tokens = micro_tokens(&fam, 1, 5, 15);
        let mut cache = KvCache::for_family(&fam);
        fwd_prefill(&fam, &view, &proj, &tokens, &mut cache).unwrap();
        let logical = 4 * 2 * fam.n_layers * cache.len() * fam.kv_dim();
        assert_eq!(cache.len_bytes(), logical);
        assert!(
            cache.byte_size() >= cache.len_bytes(),
            "capacity {} under logical {}",
            cache.byte_size(),
            cache.len_bytes()
        );
        // Paged caches account in whole pages.
        let pool = KvPool::new(fam.n_layers, fam.kv_dim(), 4, 64 * 1024).unwrap();
        let mut paged = KvCache::paged(&pool, 64);
        fwd_prefill(&fam, &view, &proj, &tokens, &mut paged).unwrap();
        assert_eq!(paged.byte_size(), 2 * pool.page_bytes(), "5 rows = 2 pages of 4");
        assert_eq!(paged.len_bytes(), logical);
        assert!(paged.byte_size() >= paged.len_bytes());
    }

    #[test]
    fn decode_validates_inputs() {
        let fam = micro_family();
        let params = ModelParams::init(&fam, 34);
        let view = ParamView::from_params(&params).unwrap();
        let proj = DenseProj { view: &view };
        let mut cache = KvCache::for_family(&fam);
        fwd_prefill(&fam, &view, &proj, &[1, 2, 3], &mut cache).unwrap();
        let mut caches = [&mut cache];
        assert!(fwd_decode(&fam, &view, &proj, &[], &mut []).is_err());
        assert!(fwd_decode(&fam, &view, &proj, &[1, 2], &mut caches).is_err());
        let big = fam.vocab as i32;
        assert!(fwd_decode(&fam, &view, &proj, &[big], &mut caches).is_err());
        assert!(fwd_prefill(&fam, &view, &proj, &[], &mut KvCache::for_family(&fam)).is_err());
    }

    fn loss_of(fam: &FamilySpec, params: &ModelParams, tokens: &[i32], b: usize, sp1: usize) -> f32 {
        let view = ParamView::from_params(params).unwrap();
        loss_and_grads(fam, &view, tokens, b, sp1).unwrap().loss
    }

    #[test]
    fn gradients_match_finite_differences() {
        let fam = micro_family();
        let params = ModelParams::init(&fam, 3);
        let (b, sp1) = (2usize, 5usize);
        let tokens = micro_tokens(&fam, b, sp1, 3);
        let view = ParamView::from_params(&params).unwrap();
        let out = loss_and_grads(&fam, &view, &tokens, b, sp1).unwrap();
        assert!(out.loss.is_finite() && out.loss > 0.0);

        let mut rng = Pcg64::new(42, 42);
        let mut checked = 0usize;
        for (pi, (pname, shape)) in fam.params.iter().enumerate() {
            let count: usize = shape.iter().product();
            for _ in 0..4 {
                let j = rng.below(count);
                let eps = 1e-2f32;
                let mut perturbed = params.clone();
                if let Value::F32 { data, .. } = &mut perturbed.values[pi] {
                    data[j] += eps;
                }
                let lp = loss_of(&fam, &perturbed, &tokens, b, sp1);
                if let Value::F32 { data, .. } = &mut perturbed.values[pi] {
                    data[j] -= 2.0 * eps;
                }
                let lm = loss_of(&fam, &perturbed, &tokens, b, sp1);
                let fd = (lp - lm) / (2.0 * eps);
                let an = out.grads[pi][j];
                let denom = fd.abs().max(an.abs());
                if denom > 0.02 {
                    assert!(
                        (fd - an).abs() <= 0.25 * denom + 5e-3,
                        "{pname}[{j}]: fd={fd} analytic={an}"
                    );
                    checked += 1;
                }
            }
        }
        assert!(checked >= 5, "only {checked} gradient probes were large enough");
    }

    #[test]
    fn micro_training_reduces_loss() {
        let fam = micro_family();
        let mut params = ModelParams::init(&fam, 9);
        let (b, sp1) = (4usize, 9usize);
        // A learnable pattern: strictly repeating token cycle.
        let tokens: Vec<i32> = (0..b * sp1).map(|i| (i % 4) as i32).collect();
        let n = fam.params.len();
        let mut m: Vec<Vec<f32>> = fam
            .params
            .iter()
            .map(|(_, sh)| vec![0f32; sh.iter().product()])
            .collect();
        let mut v = m.clone();
        let mut first = None;
        let mut last = 0f32;
        for step in 0..150 {
            let view = ParamView::from_params(&params).unwrap();
            let out = loss_and_grads(&fam, &view, &tokens, b, sp1).unwrap();
            if first.is_none() {
                first = Some(out.loss);
            }
            last = out.loss;
            for i in 0..n {
                let decay = if FamilySpec::is_norm(&fam.params[i].0) {
                    0.0
                } else {
                    ADAM_WD
                };
                let p = match &params.values[i] {
                    Value::F32 { data, .. } => data.clone(),
                    _ => unreachable!(),
                };
                let (np, nm, nv) =
                    adamw_update(&p, &m[i], &v[i], &out.grads[i], step as f32, decay);
                params.values[i] =
                    Value::from_vec_f32(fam.params[i].1.clone(), np);
                m[i] = nm;
                v[i] = nv;
            }
        }
        let first = first.unwrap();
        assert!(
            last < first - 0.8,
            "training did not reduce loss: {first} → {last}"
        );
        assert!(last.is_finite());
    }

    #[test]
    fn geglu_family_forward_and_grads_finite() {
        let fam = FamilySpec::build("micro-g", 7, 8, 1, 2, 2, 10, "geglu");
        let params = ModelParams::init(&fam, 4);
        let view = ParamView::from_params(&params).unwrap();
        let tokens = micro_tokens(&fam, 2, 4, 5);
        let out = loss_and_grads(&fam, &view, &tokens, 2, 4).unwrap();
        assert!(out.loss.is_finite());
        for g in &out.grads {
            assert!(g.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn native_exec_train_artifact_roundtrip() {
        // One train step through the full exec interface on the smallest
        // built-in family: arity and shape contract of the artifact.
        let manifest = Manifest::native();
        let fam = manifest.family("tg-2s").unwrap().clone();
        let params = ModelParams::init(&fam, 11);
        let n = params.values.len();
        let zeros: Vec<Value> = params
            .values
            .iter()
            .map(|v| {
                Value::from_vec_f32(v.shape().to_vec(), vec![0.0; v.shape().iter().product()])
            })
            .collect();
        let mut rng = Pcg64::new(13, 13);
        let tokens: Vec<i32> = (0..manifest.batch * (manifest.seq + 1))
            .map(|_| rng.below(fam.vocab) as i32)
            .collect();
        let mut inputs = Vec::with_capacity(3 * n + 2);
        inputs.extend(params.values.iter().cloned());
        inputs.extend(zeros.iter().cloned());
        inputs.extend(zeros.iter().cloned());
        inputs.push(Value::scalar_f32(0.0));
        inputs.push(Value::from_vec_i32(
            vec![manifest.batch, manifest.seq + 1],
            tokens,
        ));
        let outs = exec(&manifest, "train_tg-2s", &inputs).unwrap();
        assert_eq!(outs.len(), 3 * n + 1);
        let loss = outs.last().unwrap().f32_data().unwrap()[0];
        // Untrained on random bytes ⇒ near ln(vocab).
        assert!(loss > 3.0 && loss < 8.0, "loss={loss}");
        for (o, p) in outs[..n].iter().zip(&params.values) {
            assert_eq!(o.shape(), p.shape());
        }
    }
}
