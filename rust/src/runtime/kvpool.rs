//! Process-wide paged KV allocator: the storage layer underneath serving's
//! per-session [`KvCache`](super::native::KvCache)s.
//!
//! ## Why pages
//!
//! In the paper's low-bit serving regime the weights are nearly free
//! (2–4-bit `Q` plus a small `L·R` correction), so what actually caps
//! concurrency is per-session KV memory. A flat grow-only buffer per
//! session cannot be budgeted, shared, or evicted. This module replaces it
//! with a vLLM-style paged layout:
//!
//! * **Page**: a fixed block of [`page_tokens`](KvPool::page_tokens) token
//!   positions × `kv_dim` floats for K and V, for *all* layers
//!   (layer-major inside the page). Page size in bytes is
//!   `2 (K+V) · n_layers · page_tokens · kv_dim · 4`.
//! * **Pool**: one process-wide [`KvPool`] holds every page under a hard
//!   byte budget (`max_pages = budget / page_bytes`). Allocation order:
//!   free list → grow (until `max_pages`) → reclaim a *cached* page
//!   (refcount 0, still registered for prefix sharing) → typed
//!   [`KvError::PoolExhausted`]. Reclaim is shared-prefix-aware: pages are
//!   ranked by their chain's recency (max over the chain's pages, live
//!   references pinning it hot), so a cold prompt chain is consumed
//!   tail-first before a hot shared system prompt loses a page.
//! * **Block table**: each session maps logical position `p` to page
//!   `table[p / page_tokens]`, offset `p % page_tokens`. Tables only ever
//!   append pages; eviction happens by preempting whole sessions (the
//!   scheduler drops the session's cache, freeing its refcounts, and later
//!   *resumes* it by re-prefilling from its token history — bit-exact
//!   because K rows are pure functions of the token prefix).
//!
//! ## Prefix sharing
//!
//! K rows are stored post-RoPE at absolute positions and V rows raw, so a
//! page's contents are a pure function of the token prefix it covers.
//! After a prefill, each prompt page is **registered** in a hash index
//! under the FNV-1a hash of the token prefix up to that page's last
//! covered position (the final partial page under the hash of the whole
//! prompt). A later session with an identical prefix **adopts** the chain:
//! it increfs the pages instead of rewriting them, records the adopted
//! extent as `shared_len`, and its prefill skips the K/V stores for those
//! positions (the compute still runs — prefill logits stay bit-identical
//! to the full forward). Lookups verify the stored prefix before adopting,
//! so a hash collision can only cost sharing, never correctness.
//!
//! ## Copy-on-write
//!
//! Writes go through [`ensure`](KvPool::ensure), which runs *before* any
//! forward compute: a session about to write into a page with refcount > 1
//! first copies its own logical rows of that page into a private page.
//! Reserving ahead of compute means pool exhaustion surfaces as a clean
//! typed error with no half-written step — the scheduler can preempt a
//! session and retry.
//!
//! ## Machine-checked invariants
//!
//! The invariants above are enforced by tooling, not convention:
//! `tools/odlri-lint` statically refuses panics on this path, requires the
//! `KvError` tags below to stay in sync with their `is_*` classifiers, and
//! forbids holding the pool mutex across a forward. [`KvPool::audit`] /
//! [`KvPool::audit_tables`] dynamically cross-check refcounts,
//! registration state, and the free list against the live block tables —
//! the serving loop runs them at every tick boundary in debug builds.

use std::collections::HashMap;
use std::fmt;
use std::sync::{Arc, Mutex, MutexGuard};

use crate::tensor::Matrix;

/// Token positions per KV page. Small enough that short shared prompts
/// still resolve to whole pages, large enough that block tables stay tiny.
pub const DEFAULT_PAGE_TOKENS: usize = 16;

// ------------------------------------------------------------------ errors

/// Typed failures of the paged KV path.
///
/// The workspace's offline `anyhow` shim flattens error sources into
/// strings (no downcasting), so each variant's `Display` leads with a
/// stable tag and the `is_*` matchers classify an `anyhow::Error` by
/// scanning its `{:#}` chain. The tags are part of the API and pinned by
/// tests.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum KvError {
    /// The cache would grow past its configured position cap.
    ContextOverflow { have: usize, extra: usize, max: usize },
    /// The pool has no free, growable, or reclaimable page left.
    PoolExhausted { in_use: usize, max_pages: usize },
    /// A prompt needs more pages than the whole pool holds — no amount of
    /// preemption can ever admit it.
    PromptTooLarge { prompt_pages: usize, max_pages: usize },
    /// The replica shard backing this session's pool has been quarantined
    /// (failover drill or a real fault). The session must be migrated —
    /// re-prefilled from its token history on a surviving shard — before
    /// it can decode again.
    ReplicaFailed { shard: usize },
    /// The pool mutex was poisoned by a panicking holder. The guard was
    /// recovered (no panic propagates), but the pool's contents can no
    /// longer be trusted, so every subsequent reservation refuses with
    /// this error instead.
    LockPoisoned,
}

impl KvError {
    pub const CONTEXT_OVERFLOW_TAG: &'static str = "kv context overflow";
    pub const POOL_EXHAUSTED_TAG: &'static str = "kv pool exhausted";
    pub const PROMPT_TOO_LARGE_TAG: &'static str = "kv prompt too large";
    pub const REPLICA_FAILED_TAG: &'static str = "kv replica failed";
    pub const LOCK_POISONED_TAG: &'static str = "kv pool lock poisoned";

    fn chain_has(e: &anyhow::Error, tag: &str) -> bool {
        format!("{e:#}").contains(tag)
    }

    pub fn is_context_overflow(e: &anyhow::Error) -> bool {
        Self::chain_has(e, Self::CONTEXT_OVERFLOW_TAG)
    }

    pub fn is_pool_exhausted(e: &anyhow::Error) -> bool {
        Self::chain_has(e, Self::POOL_EXHAUSTED_TAG)
    }

    pub fn is_prompt_too_large(e: &anyhow::Error) -> bool {
        Self::chain_has(e, Self::PROMPT_TOO_LARGE_TAG)
    }

    pub fn is_replica_failed(e: &anyhow::Error) -> bool {
        Self::chain_has(e, Self::REPLICA_FAILED_TAG)
    }

    pub fn is_lock_poisoned(e: &anyhow::Error) -> bool {
        Self::chain_has(e, Self::LOCK_POISONED_TAG)
    }
}

impl fmt::Display for KvError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            KvError::ContextOverflow { have, extra, max } => write!(
                f,
                "{}: {have} cached positions + {extra} new exceed the cap of {max}",
                Self::CONTEXT_OVERFLOW_TAG
            ),
            KvError::PoolExhausted { in_use, max_pages } => write!(
                f,
                "{}: {in_use}/{max_pages} pages in use and none reclaimable",
                Self::POOL_EXHAUSTED_TAG
            ),
            KvError::PromptTooLarge {
                prompt_pages,
                max_pages,
            } => write!(
                f,
                "{}: prompt needs {prompt_pages} pages but the pool budget holds only {max_pages}",
                Self::PROMPT_TOO_LARGE_TAG
            ),
            KvError::ReplicaFailed { shard } => write!(
                f,
                "{}: shard {shard} is quarantined; migrate the session to a surviving shard",
                Self::REPLICA_FAILED_TAG
            ),
            KvError::LockPoisoned => write!(
                f,
                "{}: a holder panicked; the guard was recovered but reservations are refused",
                Self::LOCK_POISONED_TAG
            ),
        }
    }
}

impl std::error::Error for KvError {}

// ------------------------------------------------------------------- stats

/// Snapshot of pool occupancy and sharing counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    pub page_tokens: usize,
    pub page_bytes: usize,
    pub budget_bytes: usize,
    pub max_pages: usize,
    /// Pages currently holding live data (referenced or cached-for-reuse).
    pub resident_pages: usize,
    /// High-water mark of `resident_pages`.
    pub peak_resident_pages: usize,
    /// Pages ever backed by an allocation (resident-bytes high water).
    pub allocated_pages: usize,
    /// Pages adopted from the prefix index instead of recomputed storage.
    pub shared_adoptions: u64,
    /// Copy-on-write page copies taken on first divergence.
    pub cow_copies: u64,
    /// Cached (refcount-0, registered) pages reclaimed under pressure.
    pub reclaimed_pages: u64,
}

// ------------------------------------------------------------- block table

/// Per-session logical-position → page-slot map. Created empty, appended
/// to by [`KvPool::ensure`] / [`KvPool::adopt`]; every held page is
/// refcounted, released via [`KvPool::release`].
#[derive(Debug, Default)]
pub struct BlockTable {
    pages: Vec<usize>,
    /// Positions `[0, shared_len)` were adopted from the prefix index;
    /// stores for them are skipped (identical bits are already resident).
    shared_len: usize,
}

impl BlockTable {
    pub fn n_pages(&self) -> usize {
        self.pages.len()
    }

    pub fn shared_len(&self) -> usize {
        self.shared_len
    }
}

// -------------------------------------------------------------------- pool

struct PageEntry {
    k: Vec<f32>,
    v: Vec<f32>,
    refs: usize,
    /// Prefix-index key this page is registered under, if any.
    reg_key: Option<u64>,
    /// The exact token prefix whose tail this page stores — verified on
    /// adoption so hash collisions cannot alias different histories.
    reg_prefix: Option<Vec<i32>>,
    /// Chain id: the hash of the prompt's *first-page* prefix. Every page
    /// of one registered prompt (and of any prompt sharing its head)
    /// carries the same id, so reclaim can rank whole chains by their
    /// hottest page instead of per-page recency.
    reg_chain: Option<u64>,
    last_use: u64,
}

#[derive(Default)]
struct PoolInner {
    pages: Vec<PageEntry>,
    free: Vec<usize>,
    index: HashMap<u64, usize>,
    tick: u64,
    shared_adoptions: u64,
    cow_copies: u64,
    reclaimed: u64,
    peak_resident: usize,
    /// Set when a lock holder panicked and the guard was recovered; the
    /// pool then refuses new reservations with a typed
    /// [`KvError::LockPoisoned`] instead of panicking on the next lock.
    poisoned: bool,
}

/// Process-wide paged KV allocator; cheap to clone (shared state behind a
/// mutex), immutable geometry outside it. See the module docs for the
/// allocation, sharing, and eviction policy.
#[derive(Clone)]
pub struct KvPool {
    n_layers: usize,
    kv_dim: usize,
    page_tokens: usize,
    page_bytes: usize,
    budget_bytes: usize,
    max_pages: usize,
    inner: Arc<Mutex<PoolInner>>,
}

impl fmt::Debug for KvPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = self.stats();
        write!(
            f,
            "KvPool({} layers x {} kv_dim, page {} tokens, {}/{} pages resident)",
            self.n_layers, self.kv_dim, self.page_tokens, s.resident_pages, s.max_pages
        )
    }
}

impl KvPool {
    /// Bytes one page occupies: K and V panels for every layer.
    pub fn page_bytes_for(n_layers: usize, kv_dim: usize, page_tokens: usize) -> usize {
        2 * n_layers.max(1) * page_tokens.max(1) * kv_dim.max(1) * 4
    }

    pub fn new(
        n_layers: usize,
        kv_dim: usize,
        page_tokens: usize,
        budget_bytes: usize,
    ) -> anyhow::Result<KvPool> {
        let n_layers = n_layers.max(1);
        let kv_dim = kv_dim.max(1);
        let page_tokens = page_tokens.max(1);
        let page_bytes = Self::page_bytes_for(n_layers, kv_dim, page_tokens);
        let max_pages = budget_bytes / page_bytes;
        if max_pages == 0 {
            anyhow::bail!(
                "kv budget {budget_bytes} B holds no page (page = {page_tokens} tokens = {page_bytes} B)"
            );
        }
        Ok(KvPool {
            n_layers,
            kv_dim,
            page_tokens,
            page_bytes,
            budget_bytes,
            max_pages,
            inner: Arc::new(Mutex::new(PoolInner::default())),
        })
    }

    /// Pool sized so the configured concurrency never feels the budget:
    /// 2× (max_batch sessions at full context). Used when no explicit
    /// `--kv-budget` is given.
    pub fn with_default_budget(
        n_layers: usize,
        kv_dim: usize,
        max_context: usize,
        max_batch: usize,
    ) -> KvPool {
        let page_bytes = Self::page_bytes_for(n_layers, kv_dim, DEFAULT_PAGE_TOKENS);
        let pages_per = max_context.max(1).div_ceil(DEFAULT_PAGE_TOKENS);
        let budget = 2 * max_batch.max(1) * pages_per * page_bytes;
        KvPool::new(n_layers, kv_dim, DEFAULT_PAGE_TOKENS, budget)
            // lint:allow(hot-path-panic) budget = 2·max(1)·div_ceil(..)·page_bytes >= page_bytes, so max_pages >= 1
            .expect("default kv budget always holds at least one page")
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn kv_dim(&self) -> usize {
        self.kv_dim
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    pub fn page_bytes(&self) -> usize {
        self.page_bytes
    }

    pub fn max_pages(&self) -> usize {
        self.max_pages
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Resident bytes held by one session's table (its share of the pool,
    /// counting shared pages at full size).
    pub fn held_bytes(&self, table: &BlockTable) -> usize {
        table.pages.len() * self.page_bytes
    }

    pub fn stats(&self) -> PoolStats {
        let inner = self.lock();
        PoolStats {
            page_tokens: self.page_tokens,
            page_bytes: self.page_bytes,
            budget_bytes: self.budget_bytes,
            max_pages: self.max_pages,
            resident_pages: inner.pages.len() - inner.free.len(),
            peak_resident_pages: inner.peak_resident,
            allocated_pages: inner.pages.len(),
            shared_adoptions: inner.shared_adoptions,
            cow_copies: inner.cow_copies,
            reclaimed_pages: inner.reclaimed,
        }
    }

    /// Lock the pool state, recovering from mutex poisoning instead of
    /// propagating the holder's panic: the guard is taken over and the
    /// pool is flagged so fallible entry points ([`KvPool::ensure`])
    /// surface a typed [`KvError::LockPoisoned`] — infallible readers and
    /// releases keep working so in-flight sessions can wind down.
    fn lock(&self) -> MutexGuard<'_, PoolInner> {
        match self.inner.lock() {
            Ok(guard) => guard,
            Err(recovered) => {
                let mut guard = recovered.into_inner();
                guard.poisoned = true;
                guard
            }
        }
    }

    /// Whether a lock holder ever panicked (the pool refuses reservations
    /// from then on).
    pub fn is_poisoned(&self) -> bool {
        self.lock().poisoned
    }

    /// Allocate one page: free list → grow → LRU-reclaim a cached page.
    fn alloc_locked(&self, inner: &mut PoolInner) -> Result<usize, KvError> {
        let id = if let Some(id) = inner.free.pop() {
            id
        } else if inner.pages.len() < self.max_pages {
            let floats = self.n_layers * self.page_tokens * self.kv_dim;
            inner.pages.push(PageEntry {
                k: vec![0f32; floats],
                v: vec![0f32; floats],
                refs: 0,
                reg_key: None,
                reg_prefix: None,
                reg_chain: None,
                last_use: 0,
            });
            inner.pages.len() - 1
        } else {
            // Reclaim a cached page (refcount 0 but kept registered for
            // prefix sharing). Referenced pages are never reclaimed —
            // eviction of live sessions is the scheduler's job, by
            // preemption.
            //
            // Shared-prefix-aware LRU: pages are ranked by their *chain's*
            // recency (max over the chain's pages; a page referenced by a
            // live session pins its chain hot), so one cold prompt chain
            // is fully consumed before a hot shared system prompt loses a
            // single page. Within the coldest chain, the longest
            // registered prefix — the tail — goes first, so eviction only
            // ever shortens a chain from the back and later adoption
            // stops cleanly at the missing page instead of hitting a
            // mid-chain hole.
            let mut chain_recency: HashMap<u64, u64> = HashMap::new();
            for e in &inner.pages {
                if let Some(c) = e.reg_chain {
                    let r = if e.refs > 0 { u64::MAX } else { e.last_use };
                    let slot = chain_recency.entry(c).or_insert(0);
                    *slot = (*slot).max(r);
                }
            }
            let victim = inner
                .pages
                .iter()
                .enumerate()
                .filter(|(_, e)| e.refs == 0 && e.reg_key.is_some())
                .min_by_key(|(_, e)| {
                    let chain = e
                        .reg_chain
                        .and_then(|c| chain_recency.get(&c))
                        .copied()
                        .unwrap_or(e.last_use);
                    let plen = e.reg_prefix.as_ref().map_or(0, |t| t.len());
                    (chain, std::cmp::Reverse(plen), e.last_use)
                })
                .map(|(i, _)| i);
            let Some(id) = victim else {
                return Err(KvError::PoolExhausted {
                    in_use: inner.pages.len() - inner.free.len(),
                    max_pages: self.max_pages,
                });
            };
            if let Some(key) = inner.pages[id].reg_key.take() {
                inner.index.remove(&key);
            }
            inner.pages[id].reg_prefix = None;
            inner.pages[id].reg_chain = None;
            inner.reclaimed += 1;
            id
        };
        let e = &mut inner.pages[id];
        debug_assert_eq!(e.refs, 0, "allocating a referenced page");
        e.refs = 1;
        e.last_use = inner.tick;
        inner.tick += 1;
        let resident = inner.pages.len() - inner.free.len();
        inner.peak_resident = inner.peak_resident.max(resident);
        Ok(id)
    }

    fn decref_locked(inner: &mut PoolInner, id: usize, tick: u64) {
        let e = &mut inner.pages[id];
        debug_assert!(e.refs > 0, "double release of page {id}");
        e.refs -= 1;
        if e.refs == 0 {
            if e.reg_key.is_some() {
                // Keep registered pages resident as a prefix cache; mark
                // recency so reclaim takes the coldest first.
                e.last_use = tick;
            } else {
                inner.free.push(id);
            }
        }
    }

    /// Reserve capacity for `extra` more positions after `len`, taking
    /// copy-on-write copies of any shared page the session is about to
    /// write into. Runs *before* forward compute: on error nothing about
    /// the session changed and the caller can preempt + retry.
    pub(crate) fn ensure(
        &self,
        table: &mut BlockTable,
        len: usize,
        extra: usize,
    ) -> Result<(), KvError> {
        let p = self.page_tokens;
        let first_write = len.max(table.shared_len);
        let last = len + extra;
        let mut inner = self.lock();
        if inner.poisoned {
            return Err(KvError::LockPoisoned);
        }
        if first_write >= last {
            return Ok(()); // nothing will be stored (fully shared extent)
        }
        for j in first_write / p..=(last - 1) / p {
            if j < table.pages.len() {
                let pid = table.pages[j];
                if inner.pages[pid].refs > 1 {
                    // COW: copy only this session's own logical rows of
                    // the page — rows past `len` may belong to another
                    // session's divergent tail.
                    let keep = len.saturating_sub(j * p).min(p);
                    let kvd = self.kv_dim;
                    let mut kcopy = vec![0f32; self.n_layers * keep * kvd];
                    let mut vcopy = vec![0f32; self.n_layers * keep * kvd];
                    {
                        let src = &inner.pages[pid];
                        for l in 0..self.n_layers {
                            let so = l * p * kvd;
                            let d0 = l * keep * kvd;
                            kcopy[d0..d0 + keep * kvd]
                                .copy_from_slice(&src.k[so..so + keep * kvd]);
                            vcopy[d0..d0 + keep * kvd]
                                .copy_from_slice(&src.v[so..so + keep * kvd]);
                        }
                    }
                    let nid = self.alloc_locked(&mut inner)?;
                    {
                        let dst = &mut inner.pages[nid];
                        for l in 0..self.n_layers {
                            let so = l * p * kvd;
                            let d0 = l * keep * kvd;
                            dst.k[so..so + keep * kvd]
                                .copy_from_slice(&kcopy[d0..d0 + keep * kvd]);
                            dst.v[so..so + keep * kvd]
                                .copy_from_slice(&vcopy[d0..d0 + keep * kvd]);
                        }
                    }
                    inner.cow_copies += 1;
                    let tick = inner.tick;
                    Self::decref_locked(&mut inner, pid, tick);
                    table.pages[j] = nid;
                    // Rows of this page below shared_len are now private
                    // copies; the skip threshold no longer applies here.
                    table.shared_len = table.shared_len.min(j * p).min(len);
                }
            } else {
                debug_assert_eq!(j, table.pages.len(), "block table gap");
                let nid = self.alloc_locked(&mut inner)?;
                table.pages.push(nid);
            }
        }
        Ok(())
    }

    /// Store whole K/V rows (multiples of `kv_dim`) for one layer starting
    /// at logical position `base`. Rows below the table's `shared_len` are
    /// already resident (adopted) and are skipped.
    pub(crate) fn write_rows(
        &self,
        table: &BlockTable,
        layer: usize,
        base: usize,
        k: &[f32],
        v: &[f32],
    ) {
        let kvd = self.kv_dim;
        debug_assert_eq!(k.len() % kvd, 0, "kv row width");
        debug_assert_eq!(k.len(), v.len(), "k/v row count");
        let rows = k.len() / kvd;
        let start = table.shared_len.saturating_sub(base).min(rows);
        if start == rows {
            return;
        }
        let p = self.page_tokens;
        let mut inner = self.lock();
        for r in start..rows {
            let pos = base + r;
            let pid = table.pages[pos / p];
            let e = &mut inner.pages[pid];
            debug_assert!(e.refs >= 1, "write into unreferenced page");
            let o = layer * p * kvd + (pos % p) * kvd;
            e.k[o..o + kvd].copy_from_slice(&k[r * kvd..(r + 1) * kvd]);
            e.v[o..o + kvd].copy_from_slice(&v[r * kvd..(r + 1) * kvd]);
        }
    }

    /// Gather one kv-head's cached panels over positions `[0, len)`:
    /// (K, V), each (len, head_dim).
    pub(crate) fn read_head(
        &self,
        table: &BlockTable,
        layer: usize,
        g: usize,
        hd: usize,
        len: usize,
    ) -> (Matrix, Matrix) {
        let p = self.page_tokens;
        let kvd = self.kv_dim;
        let mut k = Matrix::zeros(len, hd);
        let mut v = Matrix::zeros(len, hd);
        let inner = self.lock();
        for pos in 0..len {
            let e = &inner.pages[table.pages[pos / p]];
            let o = layer * p * kvd + (pos % p) * kvd + g * hd;
            k.row_mut(pos).copy_from_slice(&e.k[o..o + hd]);
            v.row_mut(pos).copy_from_slice(&e.v[o..o + hd]);
        }
        (k, v)
    }

    /// Resolve the longest registered prefix of `tokens` to its page
    /// chain: adopt whole pages at page-boundary prefixes, then try the
    /// exact full prompt for a final partial page. Returns the adopted
    /// extent (recorded as the table's `shared_len`). The table must be
    /// empty.
    pub(crate) fn adopt(&self, table: &mut BlockTable, tokens: &[i32]) -> usize {
        debug_assert!(table.pages.is_empty(), "adopt into a used table");
        let p = self.page_tokens;
        let mut inner = self.lock();
        let mut pos = 0usize;
        loop {
            let next = pos + p;
            if next > tokens.len() {
                break;
            }
            if !Self::adopt_one(&mut inner, table, &tokens[..next]) {
                break;
            }
            pos = next;
        }
        // The tail page is registered under the hash of the *whole*
        // prompt, so adopting it is only sound when every whole page
        // before it was adopted. After a mid-chain miss (a middle page
        // was LRU-reclaimed while the tail survived — reachable because
        // recency is bumped per-page) the tail would be pushed at the
        // wrong block-table index and `shared_len` would cover positions
        // mapped to the wrong page.
        if pos == (tokens.len() / p) * p
            && pos < tokens.len()
            && Self::adopt_one(&mut inner, table, tokens)
        {
            pos = tokens.len();
        }
        table.shared_len = pos;
        pos
    }

    /// Adopt the page registered under exactly `prefix`, if any.
    fn adopt_one(inner: &mut PoolInner, table: &mut BlockTable, prefix: &[i32]) -> bool {
        let Some(&pid) = inner.index.get(&hash_tokens(prefix)) else {
            return false;
        };
        if inner.pages[pid].reg_prefix.as_deref() != Some(prefix) {
            return false; // hash collision: never alias histories
        }
        inner.pages[pid].refs += 1;
        inner.pages[pid].last_use = inner.tick;
        inner.tick += 1;
        table.pages.push(pid);
        inner.shared_adoptions += 1;
        true
    }

    /// Publish a completed prefill's pages in the prefix index: page `j`
    /// under the hash of `tokens[..min((j+1)·P, n)]`. First writer wins;
    /// already-registered pages and taken keys are left alone.
    pub(crate) fn register(&self, table: &BlockTable, tokens: &[i32]) {
        if tokens.is_empty() {
            return;
        }
        let p = self.page_tokens;
        let chain = hash_tokens(&tokens[..p.min(tokens.len())]);
        let mut inner = self.lock();
        for (j, &pid) in table.pages.iter().enumerate() {
            let end = ((j + 1) * p).min(tokens.len());
            if end <= j * p {
                break;
            }
            if inner.pages[pid].reg_key.is_some() {
                continue;
            }
            let key = hash_tokens(&tokens[..end]);
            if inner.index.contains_key(&key) {
                continue;
            }
            inner.pages[pid].reg_key = Some(key);
            inner.pages[pid].reg_prefix = Some(tokens[..end].to_vec());
            inner.pages[pid].reg_chain = Some(chain);
            inner.index.insert(key, pid);
        }
    }

    /// Duplicate a table, increffing every page (both copies then write
    /// through copy-on-write).
    pub(crate) fn clone_table(&self, table: &BlockTable) -> BlockTable {
        let mut inner = self.lock();
        for &pid in &table.pages {
            inner.pages[pid].refs += 1;
        }
        BlockTable {
            pages: table.pages.clone(),
            shared_len: table.shared_len,
        }
    }

    /// Drop a session's references; registered pages stay cached for
    /// prefix sharing, unregistered ones return to the free list.
    pub(crate) fn release(&self, table: &mut BlockTable) {
        let mut inner = self.lock();
        inner.tick += 1;
        let tick = inner.tick;
        for i in 0..table.pages.len() {
            let pid = table.pages[i];
            Self::decref_locked(&mut inner, pid, tick);
        }
        table.pages.clear();
        table.shared_len = 0;
    }

    /// Roll a session's table back to `new_len` positions (speculative-
    /// decode rejection): pages wholly past the new length are decref'd
    /// (registered ones stay cached and adoptable — their contents are
    /// still a valid prefix of the released history).
    ///
    /// The boundary page needs care, because the session will rewrite its
    /// rows at positions `>= new_len` on the next decode:
    ///
    /// * refs > 1 (adopted/cloned, still shared): leave it alone —
    ///   [`ensure`](Self::ensure) copy-on-writes before any store, so the
    ///   shared bits can never be mutated through this table.
    /// * refs == 1 but registered with a prefix extending past `new_len`:
    ///   deregister it. The in-place rewrite is fine for *this* session,
    ///   but a later adopter must not resolve the stale prefix hash to
    ///   rows about to be overwritten. Deregistering (rather than COW)
    ///   keeps rollback infallible — no allocation, no pool pressure.
    ///
    /// Finally the table's `shared_len` is clamped to `new_len`: positions
    /// past the rollback point are no longer "already resident", so
    /// [`write_rows`](Self::write_rows) must stop skipping them.
    pub(crate) fn truncate(&self, table: &mut BlockTable, new_len: usize) {
        let p = self.page_tokens;
        let keep = new_len.div_ceil(p);
        let mut inner = self.lock();
        inner.tick += 1;
        let tick = inner.tick;
        while table.pages.len() > keep {
            let Some(pid) = table.pages.pop() else { break };
            Self::decref_locked(&mut inner, pid, tick);
        }
        if let Some(&pid) = table.pages.last() {
            let e = &mut inner.pages[pid];
            if e.refs == 1 && e.reg_prefix.as_ref().is_some_and(|prefix| prefix.len() > new_len) {
                let key = e.reg_key.take();
                e.reg_prefix = None;
                e.reg_chain = None;
                if let Some(key) = key {
                    inner.index.remove(&key);
                }
            }
        }
        table.shared_len = table.shared_len.min(new_len);
    }

    // ------------------------------------------------------ debug auditor

    /// Whether two handles share one underlying pool (used by the serving
    /// loop to group per-session caches by pool before auditing).
    pub fn ptr_eq(&self, other: &KvPool) -> bool {
        Arc::ptr_eq(&self.inner, &other.inner)
    }

    /// Cross-check the pool's internal bookkeeping: free-list sanity,
    /// page-buffer geometry, all-or-nothing registration state, the
    /// `index` ↔ `reg_key` bijection, no orphaned pages, and the
    /// peak-resident high-water mark. Returns a description of the first
    /// violated invariant. Pure read; takes the lock once.
    pub fn audit(&self) -> Result<(), String> {
        let inner = self.lock();
        self.audit_impl(&inner, None)
    }

    /// [`audit`](Self::audit) plus a refcount cross-check against the
    /// *complete* set of live block tables on this pool: every table entry
    /// must be resident, every page's refcount must equal its occurrence
    /// count across the tables, and no table may claim a shared extent
    /// beyond the positions it maps. With `tables` empty this is the
    /// no-leak check — after the last session drains, every refcount must
    /// be zero (registered pages may stay cached, but nothing may pin
    /// them).
    pub fn audit_tables(&self, tables: &[&BlockTable]) -> Result<(), String> {
        let inner = self.lock();
        self.audit_impl(&inner, Some(tables))
    }

    fn audit_impl(&self, inner: &PoolInner, tables: Option<&[&BlockTable]>) -> Result<(), String> {
        let n = inner.pages.len();
        let floats = self.n_layers * self.page_tokens * self.kv_dim;
        if n > self.max_pages {
            return Err(format!(
                "{n} pages allocated but the budget holds only {}",
                self.max_pages
            ));
        }
        let mut free = vec![false; n];
        for &id in &inner.free {
            if id >= n {
                return Err(format!("free-list entry {id} out of range ({n} pages)"));
            }
            if free[id] {
                return Err(format!("page {id} appears twice in the free list"));
            }
            free[id] = true;
            let e = &inner.pages[id];
            if e.refs != 0 {
                return Err(format!("free page {id} still has {} refs", e.refs));
            }
            if e.reg_key.is_some() {
                return Err(format!("free page {id} is still registered"));
            }
        }
        for (id, e) in inner.pages.iter().enumerate() {
            if e.k.len() != floats || e.v.len() != floats {
                return Err(format!(
                    "page {id} buffers hold {}/{} floats but geometry says {floats}",
                    e.k.len(),
                    e.v.len()
                ));
            }
            let full = e.reg_key.is_some() && e.reg_prefix.is_some() && e.reg_chain.is_some();
            let none = e.reg_key.is_none() && e.reg_prefix.is_none() && e.reg_chain.is_none();
            if !full && !none {
                return Err(format!("page {id} has partial registration state"));
            }
            if !free[id] && e.refs == 0 && e.reg_key.is_none() {
                return Err(format!(
                    "page {id} is orphaned: not free, not referenced, not registered"
                ));
            }
        }
        for (&key, &pid) in &inner.index {
            if pid >= n {
                return Err(format!("index key {key:#x} points past the page vec ({pid})"));
            }
            if inner.pages[pid].reg_key != Some(key) {
                return Err(format!(
                    "index key {key:#x} maps to page {pid}, which is registered differently"
                ));
            }
        }
        let registered = inner.pages.iter().filter(|e| e.reg_key.is_some()).count();
        if registered != inner.index.len() {
            return Err(format!(
                "{registered} pages carry a reg_key but the index holds {} entries",
                inner.index.len()
            ));
        }
        let resident = n - inner.free.len();
        if inner.peak_resident < resident {
            return Err(format!(
                "peak_resident {} below current resident {resident}",
                inner.peak_resident
            ));
        }
        let Some(tables) = tables else {
            return Ok(());
        };
        let mut occ = vec![0usize; n];
        for (ti, t) in tables.iter().enumerate() {
            for &pid in &t.pages {
                if pid >= n {
                    return Err(format!("table {ti} maps a position to nonexistent page {pid}"));
                }
                if free[pid] {
                    return Err(format!("table {ti} holds freed page {pid}"));
                }
                occ[pid] += 1;
            }
            if t.shared_len > t.pages.len() * self.page_tokens {
                return Err(format!(
                    "table {ti} claims shared_len {} over only {} mapped positions",
                    t.shared_len,
                    t.pages.len() * self.page_tokens
                ));
            }
        }
        for (id, e) in inner.pages.iter().enumerate() {
            if e.refs != occ[id] {
                return Err(format!(
                    "page {id} has {} refs but appears {} times across {} live tables",
                    e.refs,
                    occ[id],
                    tables.len()
                ));
            }
        }
        Ok(())
    }
}

/// FNV-1a over the little-endian token bytes.
fn hash_tokens(tokens: &[i32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tiny geometry: 2 layers, kv_dim 4, 4 positions per page.
    fn pool(pages: usize) -> KvPool {
        let pb = KvPool::page_bytes_for(2, 4, 4);
        KvPool::new(2, 4, 4, pages * pb).unwrap()
    }

    fn row(tag: f32, pos: usize) -> Vec<f32> {
        (0..4).map(|j| tag + pos as f32 + j as f32 * 0.01).collect()
    }

    /// Fill positions [base, base+n) of every layer with recognizable rows.
    fn fill(p: &KvPool, t: &BlockTable, base: usize, n: usize, tag: f32) {
        for layer in 0..2 {
            for pos in base..base + n {
                let r = row(tag + layer as f32 * 100.0, pos);
                p.write_rows(t, layer, pos, &r, &r);
            }
        }
    }

    #[test]
    fn budget_bounds_allocation_and_exhaustion_is_typed() {
        let p = pool(3);
        assert_eq!(p.max_pages(), 3);
        let mut t = BlockTable::default();
        p.ensure(&mut t, 0, 12).unwrap(); // 3 pages of 4
        assert_eq!(t.n_pages(), 3);
        let err = p.ensure(&mut t, 12, 1).unwrap_err();
        assert!(matches!(err, KvError::PoolExhausted { .. }));
        assert!(err.to_string().contains(KvError::POOL_EXHAUSTED_TAG));
        let stats = p.stats();
        assert_eq!(stats.resident_pages, 3);
        assert!(stats.resident_pages <= stats.max_pages, "over-allocated");
        p.release(&mut t);
        assert_eq!(p.stats().resident_pages, 0);
        // Error tags classify through the flattened anyhow chain.
        let e = anyhow::Error::from(KvError::PoolExhausted { in_use: 3, max_pages: 3 })
            .context("decode step");
        assert!(KvError::is_pool_exhausted(&e));
        assert!(!KvError::is_context_overflow(&e));
    }

    #[test]
    fn poisoned_lock_recovers_to_a_typed_error() {
        // A thread panicking while holding the pool mutex must not turn
        // the next lock into a panic: the guard is recovered, the pool is
        // flagged, and reservations refuse with a typed KvError.
        let p = pool(3);
        let mut t = BlockTable::default();
        p.ensure(&mut t, 0, 4).unwrap();
        let clone = p.clone();
        let holder = std::thread::spawn(move || {
            let _guard = clone.inner.lock().unwrap();
            panic!("poison the pool mutex");
        });
        assert!(holder.join().is_err(), "holder thread must panic");
        assert!(p.is_poisoned());
        let err = p.ensure(&mut t, 4, 1).unwrap_err();
        assert!(matches!(err, KvError::LockPoisoned));
        assert!(err.to_string().contains(KvError::LOCK_POISONED_TAG));
        let e = anyhow::Error::from(err).context("decode step");
        assert!(KvError::is_lock_poisoned(&e));
        assert!(!KvError::is_pool_exhausted(&e));
        // Infallible paths still work so sessions can wind down.
        let _ = p.stats();
        p.release(&mut t);
        assert_eq!(p.stats().resident_pages, 0);
        // Replica-failure errors classify through the chain the same way.
        let rf = anyhow::Error::from(KvError::ReplicaFailed { shard: 1 }).context("decode step");
        assert!(KvError::is_replica_failed(&rf));
        assert!(!KvError::is_lock_poisoned(&rf));
    }

    #[test]
    fn rows_roundtrip_across_page_boundaries() {
        let p = pool(4);
        let mut t = BlockTable::default();
        p.ensure(&mut t, 0, 10).unwrap();
        fill(&p, &t, 0, 10, 1000.0);
        for layer in 0..2 {
            let (k, v) = p.read_head(&t, layer, 0, 4, 10);
            for pos in 0..10 {
                let want = row(1000.0 + layer as f32 * 100.0, pos);
                assert_eq!(k.row(pos), &want[..], "layer {layer} pos {pos}");
                assert_eq!(v.row(pos), &want[..]);
            }
        }
        assert_eq!(p.held_bytes(&t), 3 * p.page_bytes());
        p.release(&mut t);
    }

    #[test]
    fn prefix_adoption_shares_pages_and_cow_isolates_divergence() {
        let p = pool(8);
        let tokens: Vec<i32> = (0..10).collect();
        // Session A prefilled 10 positions and registered them.
        let mut a = BlockTable::default();
        assert_eq!(p.adopt(&mut a, &tokens), 0, "empty index adopts nothing");
        p.ensure(&mut a, 0, 10).unwrap();
        fill(&p, &a, 0, 10, 0.0);
        p.register(&a, &tokens);

        // Session B with the identical prompt adopts the full chain: two
        // whole pages plus the partial tail page.
        let mut b = BlockTable::default();
        let shared = p.adopt(&mut b, &tokens);
        assert_eq!(shared, 10);
        assert_eq!(b.n_pages(), 3);
        assert_eq!(b.shared_len(), 10);
        assert_eq!(p.stats().shared_adoptions, 3);
        assert_eq!(p.stats().resident_pages, 3, "no new storage for B");

        // Adopted rows read back bit-identically without any write.
        let (kb, _) = p.read_head(&b, 1, 0, 4, 10);
        for pos in 0..10 {
            assert_eq!(kb.row(pos), &row(100.0, pos)[..]);
        }

        // B extends: position 10 lands in the shared tail page → COW.
        p.ensure(&mut b, 10, 1).unwrap();
        assert_eq!(p.stats().cow_copies, 1);
        fill(&p, &b, 10, 1, 5000.0);
        // B sees its kept prefix rows plus the divergent row...
        let (kb, _) = p.read_head(&b, 0, 0, 4, 11);
        assert_eq!(kb.row(9), &row(0.0, 9)[..]);
        assert_eq!(kb.row(10), &row(5000.0, 10)[..]);
        // ...and A's pages are untouched.
        let (ka, _) = p.read_head(&a, 0, 0, 4, 10);
        for pos in 0..10 {
            assert_eq!(ka.row(pos), &row(0.0, pos)[..]);
        }

        // A shorter prompt sharing only the first page adopts exactly it.
        let mut c = BlockTable::default();
        let short: Vec<i32> = (0..6).collect();
        assert_eq!(p.adopt(&mut c, &short), 4, "whole first page only");
        p.release(&mut a);
        p.release(&mut b);
        p.release(&mut c);
    }

    #[test]
    fn reclaim_shortens_a_chain_tail_first() {
        // Register a 3-page chain (two whole pages + partial tail), then
        // bump only the *first* page's recency. Per-page LRU would evict
        // the middle page — leaving a hole that forfeits the whole chain.
        // Chain-aware reclaim must take the tail instead, so the surviving
        // head pages still adopt cleanly.
        let p = pool(3);
        let tokens: Vec<i32> = (0..10).collect();
        let mut a = BlockTable::default();
        p.ensure(&mut a, 0, 10).unwrap();
        fill(&p, &a, 0, 10, 0.0);
        p.register(&a, &tokens);
        p.release(&mut a); // all three pages cached, equal recency
        // First-page-only adoption bumps page 0, leaving the middle page
        // the per-page-coldest.
        let mut b = BlockTable::default();
        assert_eq!(p.adopt(&mut b, &tokens[..4]), 4);
        p.release(&mut b);
        // One page of fresh demand: the chain loses its *tail* page.
        let mut c = BlockTable::default();
        p.ensure(&mut c, 0, 4).unwrap();
        fill(&p, &c, 0, 4, 7000.0);
        assert_eq!(p.stats().reclaimed_pages, 1);
        // Both whole head pages still adopt; extent ends at the evicted
        // tail.
        let mut d = BlockTable::default();
        let shared = p.adopt(&mut d, &tokens);
        assert_eq!(shared, 8, "tail-first reclaim must keep the chain head");
        assert_eq!(d.n_pages(), 2);
        assert_eq!(d.shared_len(), 8);
        let (k, _) = p.read_head(&d, 0, 0, 4, 8);
        for pos in 0..8 {
            assert_eq!(k.row(pos), &row(0.0, pos)[..]);
        }
        p.release(&mut c);
        p.release(&mut d);
    }

    #[test]
    fn mid_chain_gap_stops_adoption_before_the_tail() {
        // Defense-in-depth behind the eviction order: if a chain ends up
        // with a hole at a middle page (reachable via first-writer-wins
        // registration collisions), re-adopting the full prompt must stop
        // at the miss — grafting the surviving tail page in at block
        // index 1 would silently map positions 4..8 to the wrong rows.
        let p = pool(3);
        let tokens: Vec<i32> = (0..10).collect();
        let mut a = BlockTable::default();
        p.ensure(&mut a, 0, 10).unwrap();
        fill(&p, &a, 0, 10, 0.0);
        p.register(&a, &tokens);
        p.release(&mut a);
        // Simulate the hole: deregister exactly the middle page.
        {
            let mut inner = p.lock();
            let key = hash_tokens(&tokens[..8]);
            let pid = inner.index.remove(&key).expect("middle page registered");
            inner.pages[pid].reg_key = None;
            inner.pages[pid].reg_prefix = None;
            inner.pages[pid].reg_chain = None;
            inner.free.push(pid);
        }
        let mut d = BlockTable::default();
        let shared = p.adopt(&mut d, &tokens);
        assert_eq!(shared, 4, "adoption ran past a mid-chain gap");
        assert_eq!(d.n_pages(), 1);
        assert_eq!(d.shared_len(), 4);
        let (k, _) = p.read_head(&d, 0, 0, 4, 4);
        for pos in 0..4 {
            assert_eq!(k.row(pos), &row(0.0, pos)[..]);
        }
        p.release(&mut d);
    }

    #[test]
    fn hot_shared_prompt_survives_pressure_that_reclaims_a_cold_chain() {
        // Two 2-page chains: H (a shared system prompt, recently adopted)
        // and C (cold, untouched since registration). Two pages of fresh
        // demand must consume chain C entirely — H stays fully adoptable
        // even though H's *tail* page is per-page older than C's pages.
        let p = pool(4);
        let hot: Vec<i32> = (0..8).collect();
        let cold: Vec<i32> = (100..108).collect();
        let mut h = BlockTable::default();
        p.ensure(&mut h, 0, 8).unwrap();
        fill(&p, &h, 0, 8, 0.0);
        p.register(&h, &hot);
        p.release(&mut h);
        let mut c = BlockTable::default();
        p.ensure(&mut c, 0, 8).unwrap();
        fill(&p, &c, 0, 8, 3000.0);
        p.register(&c, &cold);
        p.release(&mut c);
        // Touch only H's first page: its tail page is now the per-page
        // LRU victim, but its *chain* is the hottest thing in the pool.
        let mut b = BlockTable::default();
        assert_eq!(p.adopt(&mut b, &hot[..4]), 4);
        p.release(&mut b);
        // Two pages of fresh demand.
        let mut f = BlockTable::default();
        p.ensure(&mut f, 0, 8).unwrap();
        fill(&p, &f, 0, 8, 9000.0);
        assert_eq!(p.stats().reclaimed_pages, 2);
        // The hot system prompt still adopts in full...
        let mut d = BlockTable::default();
        assert_eq!(p.adopt(&mut d, &hot), 8, "hot chain lost a page");
        let (k, _) = p.read_head(&d, 0, 0, 4, 8);
        for pos in 0..8 {
            assert_eq!(k.row(pos), &row(0.0, pos)[..]);
        }
        // ...and the cold chain is gone.
        let mut e = BlockTable::default();
        assert_eq!(p.adopt(&mut e, &cold), 0, "cold chain survived");
        p.release(&mut f);
        p.release(&mut d);
    }

    #[test]
    fn different_tokens_never_adopt() {
        let p = pool(4);
        let mut a = BlockTable::default();
        let tokens: Vec<i32> = (0..8).collect();
        p.ensure(&mut a, 0, 8).unwrap();
        fill(&p, &a, 0, 8, 0.0);
        p.register(&a, &tokens);
        let mut b = BlockTable::default();
        let other: Vec<i32> = (100..108).collect();
        assert_eq!(p.adopt(&mut b, &other), 0);
        p.release(&mut a);
    }

    #[test]
    fn lru_reclaims_cached_pages_under_pressure() {
        let p = pool(2);
        // Register a one-page chain, then release it: the page stays
        // resident as prefix cache.
        let t1: Vec<i32> = (0..4).collect();
        let mut a = BlockTable::default();
        p.ensure(&mut a, 0, 4).unwrap();
        fill(&p, &a, 0, 4, 0.0);
        p.register(&a, &t1);
        p.release(&mut a);
        assert_eq!(p.stats().resident_pages, 1);
        // While cached, it is adoptable...
        let mut b = BlockTable::default();
        assert_eq!(p.adopt(&mut b, &t1), 4);
        p.release(&mut b);
        // ...until a 2-page demand forces reclaiming it.
        let mut c = BlockTable::default();
        p.ensure(&mut c, 0, 8).unwrap();
        let s = p.stats();
        assert_eq!(s.reclaimed_pages, 1);
        assert_eq!(s.resident_pages, 2);
        assert!(s.resident_pages <= s.max_pages);
        // The reclaimed page's index entry is gone: no stale adoption.
        let mut d = BlockTable::default();
        assert_eq!(p.adopt(&mut d, &t1), 0);
        p.release(&mut c);
    }

    #[test]
    fn truncate_frees_suffix_pages_and_clamps_shared_len() {
        let p = pool(4);
        let mut t = BlockTable::default();
        p.ensure(&mut t, 0, 10).unwrap();
        fill(&p, &t, 0, 10, 0.0);
        assert_eq!(t.n_pages(), 3);
        // Unregistered suffix pages go straight back to the free list.
        p.truncate(&mut t, 5);
        assert_eq!(t.n_pages(), 2);
        assert_eq!(p.stats().resident_pages, 2);
        // Kept rows are untouched; re-extending rewrites from position 5.
        p.ensure(&mut t, 5, 3).unwrap();
        fill(&p, &t, 5, 3, 4000.0);
        let (k, _) = p.read_head(&t, 0, 0, 4, 8);
        for pos in 0..5 {
            assert_eq!(k.row(pos), &row(0.0, pos)[..]);
        }
        for pos in 5..8 {
            assert_eq!(k.row(pos), &row(4000.0, pos)[..]);
        }
        // Truncating to zero releases everything.
        p.truncate(&mut t, 0);
        assert_eq!(t.n_pages(), 0);
        assert_eq!(t.shared_len(), 0);
        assert_eq!(p.stats().resident_pages, 0);
    }

    #[test]
    fn truncate_deregisters_the_rolled_back_boundary_page() {
        // A registered prefix page whose extent runs past the rollback
        // point, with no other holder: the session will rewrite rows
        // inside the registered extent in place, so the stale hash must
        // leave the index — a later identical prompt must stop adopting
        // at the still-valid head, never resolve into rewritten rows.
        let p = pool(4);
        let tokens: Vec<i32> = (0..10).collect();
        let mut a = BlockTable::default();
        p.ensure(&mut a, 0, 10).unwrap();
        fill(&p, &a, 0, 10, 0.0);
        p.register(&a, &tokens);
        p.truncate(&mut a, 6); // boundary page covered tokens[..8]
        assert_eq!(a.n_pages(), 2);
        // The rewrite lands in place — no COW, the page is private now.
        p.ensure(&mut a, 6, 1).unwrap();
        assert_eq!(p.stats().cow_copies, 0);
        fill(&p, &a, 6, 1, 8000.0);
        // Adoption of the original prompt stops at the intact first page.
        let mut d = BlockTable::default();
        assert_eq!(p.adopt(&mut d, &tokens), 4, "stale boundary page adopted");
        assert_eq!(d.n_pages(), 1);
        p.release(&mut a);
        p.release(&mut d);
    }

    #[test]
    fn truncate_keeps_shared_boundary_page_and_cow_isolates_rewrite() {
        // The boundary page is still referenced by an adopter: rollback
        // must not mutate or deregister it — the next write through the
        // truncated table copy-on-writes, and the shared bits survive.
        let p = pool(8);
        let tokens: Vec<i32> = (0..10).collect();
        let mut a = BlockTable::default();
        p.ensure(&mut a, 0, 10).unwrap();
        fill(&p, &a, 0, 10, 0.0);
        p.register(&a, &tokens);
        let mut b = BlockTable::default();
        assert_eq!(p.adopt(&mut b, &tokens), 10);
        // A rolls back into the shared middle page and diverges.
        p.truncate(&mut a, 5);
        p.ensure(&mut a, 5, 1).unwrap();
        assert_eq!(p.stats().cow_copies, 1, "shared boundary page must COW");
        fill(&p, &a, 5, 1, 8000.0);
        let (ka, _) = p.read_head(&a, 0, 0, 4, 6);
        assert_eq!(ka.row(4), &row(0.0, 4)[..]);
        assert_eq!(ka.row(5), &row(8000.0, 5)[..]);
        // B's adopted history is bit-intact...
        let (kb, _) = p.read_head(&b, 0, 0, 4, 10);
        for pos in 0..10 {
            assert_eq!(kb.row(pos), &row(0.0, pos)[..]);
        }
        // ...and the registration survived: a third session still adopts
        // the full original prompt.
        let mut c = BlockTable::default();
        assert_eq!(p.adopt(&mut c, &tokens), 10);
        p.release(&mut a);
        p.release(&mut b);
        p.release(&mut c);
    }

    #[test]
    fn truncate_into_adopted_extent_clamps_shared_len_so_rewrites_store() {
        // An adopter rolls back *into* its adopted extent. Without the
        // shared_len clamp, `ensure` would see the whole write range as
        // "already resident" (no COW) and `write_rows` would silently
        // skip the stores — the session would keep serving the donor's
        // rows for positions it has logically rewritten.
        let p = pool(8);
        let tokens: Vec<i32> = (0..10).collect();
        let mut a = BlockTable::default();
        p.ensure(&mut a, 0, 10).unwrap();
        fill(&p, &a, 0, 10, 0.0);
        p.register(&a, &tokens);
        let mut b = BlockTable::default();
        assert_eq!(p.adopt(&mut b, &tokens), 10);
        assert_eq!(b.shared_len(), 10);
        p.truncate(&mut b, 5);
        assert_eq!(b.shared_len(), 5, "rollback must clamp the skip extent");
        p.ensure(&mut b, 5, 1).unwrap();
        assert_eq!(p.stats().cow_copies, 1);
        fill(&p, &b, 5, 1, 6000.0);
        let (kb, _) = p.read_head(&b, 0, 0, 4, 6);
        assert_eq!(kb.row(5), &row(6000.0, 5)[..], "rewrite was skipped");
        // The donor still reads its original rows.
        let (ka, _) = p.read_head(&a, 0, 0, 4, 10);
        assert_eq!(ka.row(5), &row(0.0, 5)[..]);
        p.release(&mut a);
        p.release(&mut b);
    }

    #[test]
    fn truncate_at_page_boundary_keeps_registration_and_caches_the_tail() {
        // Rolling back to exactly a page boundary: the boundary page's
        // registered extent is untouched (future writes land in fresh
        // pages), so its registration stays; the popped registered tail
        // drops to refcount 0 and stays cached for adoption.
        let p = pool(4);
        let tokens: Vec<i32> = (0..10).collect();
        let mut a = BlockTable::default();
        p.ensure(&mut a, 0, 10).unwrap();
        fill(&p, &a, 0, 10, 0.0);
        p.register(&a, &tokens);
        p.truncate(&mut a, 8);
        assert_eq!(a.n_pages(), 2);
        assert_eq!(p.stats().resident_pages, 3, "registered tail stays cached");
        // Both whole head pages still adopt; the cached tail completes
        // the chain for an identical full prompt.
        let mut d = BlockTable::default();
        assert_eq!(p.adopt(&mut d, &tokens), 10);
        p.release(&mut a);
        p.release(&mut d);
    }

    #[test]
    fn clone_table_shares_then_cow_on_write() {
        let p = pool(4);
        let mut a = BlockTable::default();
        p.ensure(&mut a, 0, 6).unwrap();
        fill(&p, &a, 0, 6, 0.0);
        let mut b = p.clone_table(&a);
        assert_eq!(p.stats().resident_pages, 2, "clone allocates nothing");
        // Writer into the shared tail page takes a private copy.
        p.ensure(&mut b, 6, 1).unwrap();
        assert_eq!(p.stats().cow_copies, 1);
        fill(&p, &b, 6, 1, 9000.0);
        let (ka, _) = p.read_head(&a, 0, 0, 4, 6);
        assert_eq!(ka.row(5), &row(0.0, 5)[..]);
        p.release(&mut a);
        p.release(&mut b);
    }

    #[test]
    fn audit_passes_through_share_cow_reclaim_and_release() {
        let p = pool(4);
        let tokens: Vec<i32> = (0..10).collect();
        let mut a = BlockTable::default();
        p.ensure(&mut a, 0, 10).unwrap();
        fill(&p, &a, 0, 10, 0.0);
        p.register(&a, &tokens);
        p.audit_tables(&[&a]).unwrap();
        // Adoption: refcounts double on the shared chain.
        let mut b = BlockTable::default();
        assert_eq!(p.adopt(&mut b, &tokens), 10);
        p.audit_tables(&[&a, &b]).unwrap();
        // COW on the shared tail page.
        p.ensure(&mut b, 10, 1).unwrap();
        assert_eq!(p.stats().cow_copies, 1);
        p.audit_tables(&[&a, &b]).unwrap();
        // Release A: its registered pages stay cached at refcount B-only.
        p.release(&mut a);
        p.audit_tables(&[&b]).unwrap();
        // Exhaust the pool so a cached page is reclaimed.
        p.release(&mut b);
        let mut c = BlockTable::default();
        p.ensure(&mut c, 0, 16).unwrap();
        assert!(p.stats().reclaimed_pages > 0, "reclaim exercised");
        p.audit_tables(&[&c]).unwrap();
        // Drain: the no-leak check — every refcount back to zero.
        p.release(&mut c);
        p.audit_tables(&[]).unwrap();
        p.audit().unwrap();
    }

    #[test]
    fn audit_rejects_corrupted_state() {
        // A leaked reference: a table the auditor is not told about still
        // pins pages, so the empty-table no-leak check must fail.
        let p = pool(4);
        let mut a = BlockTable::default();
        p.ensure(&mut a, 0, 4).unwrap();
        let err = p.audit_tables(&[]).unwrap_err();
        assert!(err.contains("refs"), "unexpected report: {err}");
        // A table listed twice claims more occurrences than refs back it.
        let err = p.audit_tables(&[&a, &a]).unwrap_err();
        assert!(err.contains("refs"), "unexpected report: {err}");
        p.audit_tables(&[&a]).unwrap();
        // A hand-built table pointing at a freed page is caught.
        p.release(&mut a);
        let ghost = BlockTable {
            pages: vec![0],
            shared_len: 0,
        };
        let err = p.audit_tables(&[&ghost]).unwrap_err();
        assert!(
            err.contains("freed") || err.contains("refs"),
            "unexpected report: {err}"
        );
        // shared_len past the mapped extent is caught.
        let mut d = BlockTable::default();
        p.ensure(&mut d, 0, 4).unwrap();
        let bogus = BlockTable {
            pages: d.pages.clone(),
            shared_len: 99,
        };
        let err = p.audit_tables(&[&bogus]).unwrap_err();
        assert!(err.contains("shared_len"), "unexpected report: {err}");
        p.release(&mut d);
    }

    #[test]
    fn pool_identity_is_by_shared_state() {
        let p = pool(2);
        let q = p.clone();
        assert!(p.ptr_eq(&q));
        assert!(!p.ptr_eq(&pool(2)));
    }
}
