//! Artifact runtime: executes the model entry points (`fwd_*`,
//! `fwd_fused_*`, `train_*`, `capture_*`, `kernel_*`) behind one interface
//! with two interchangeable engines:
//!
//! * **Native** (always available) — the pure-Rust engine in [`native`],
//!   which implements the same artifact semantics with the blocked
//!   multithreaded kernels from [`crate::tensor`] and [`crate::fused`]. No
//!   files are needed: when no `artifacts/` directory exists, a synthesized
//!   manifest ([`Manifest::native`]) describes the built-in families.
//! * **XLA/PJRT** (feature `xla`) — loads the AOT HLO-text artifacts
//!   produced by `python/compile/aot.py` and executes them on the CPU PJRT
//!   client. Gated because the binding crate is not in the offline vendor
//!   set; see `Cargo.toml`.
//!
//! [`Runtime::open`] prefers XLA when compiled in *and* a manifest exists,
//! and falls back to the native engine otherwise, so every pipeline, bench,
//! example, and test runs artifact-free.

mod manifest;
pub mod kvpool;
pub mod native;
#[cfg(feature = "xla")]
mod pjrt;

pub use manifest::{
    ArtifactSpec, FamilySpec, IoSpec, Manifest, NATIVE_BATCH, NATIVE_FUSED_RANK, NATIVE_SEQ,
};

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::Matrix;

/// A tensor crossing the runtime boundary (f32 or i32, arbitrary rank).
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Value {
    pub fn from_matrix(m: &Matrix) -> Value {
        Value::F32 {
            shape: vec![m.rows(), m.cols()],
            data: m.as_slice().to_vec(),
        }
    }

    pub fn from_vec_f32(shape: Vec<usize>, data: Vec<f32>) -> Value {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape/data mismatch"
        );
        Value::F32 { shape, data }
    }

    pub fn from_vec_i32(shape: Vec<usize>, data: Vec<i32>) -> Value {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape/data mismatch"
        );
        Value::I32 { shape, data }
    }

    pub fn scalar_f32(v: f32) -> Value {
        Value::F32 {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32 { shape, .. } => shape,
            Value::I32 { shape, .. } => shape,
        }
    }

    pub fn f32_data(&self) -> Result<&[f32]> {
        match self {
            Value::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 value"),
        }
    }

    pub fn i32_data(&self) -> Result<&[i32]> {
        match self {
            Value::I32 { data, .. } => Ok(data),
            _ => bail!("expected i32 value"),
        }
    }

    /// Interpret as a 2-D matrix (rank ≤ 2 required; rank-1/0 become 1×n).
    pub fn to_matrix(&self) -> Result<Matrix> {
        let data = self.f32_data()?.to_vec();
        let shape = self.shape();
        let (r, c) = match shape.len() {
            0 => (1, 1),
            1 => (1, shape[0]),
            2 => (shape[0], shape[1]),
            _ => bail!("to_matrix on rank-{} value", shape.len()),
        };
        Ok(Matrix::from_vec(r, c, data))
    }

    /// Flatten leading axes: (a, b, c) → (a·b, c) — used for logits.
    pub fn to_matrix_2d(&self) -> Result<Matrix> {
        let data = self.f32_data()?.to_vec();
        let shape = self.shape();
        let Some(&last) = shape.last() else {
            return Ok(Matrix::from_vec(1, 1, data));
        };
        let lead: usize = shape[..shape.len() - 1].iter().product();
        Ok(Matrix::from_vec(lead, last, data))
    }
}

enum Engine {
    Native,
    #[cfg(feature = "xla")]
    Xla(pjrt::PjrtEngine),
}

/// The runtime: a manifest plus an execution engine.
pub struct Runtime {
    pub manifest: Manifest,
    engine: Engine,
}

#[cfg(feature = "xla")]
fn engine_for(dir: &Path) -> Result<Engine> {
    Ok(Engine::Xla(pjrt::PjrtEngine::open(dir)?))
}

#[cfg(not(feature = "xla"))]
fn engine_for(_dir: &Path) -> Result<Engine> {
    Ok(Engine::Native)
}

impl Runtime {
    /// Open the artifact directory. With the `xla` feature and a manifest
    /// present this compiles HLO artifacts lazily through PJRT; otherwise
    /// the native engine serves the manifest (a synthesized one when the
    /// directory has no `manifest.json`).
    pub fn open(dir: &Path) -> Result<Runtime> {
        let mpath = dir.join("manifest.json");
        if mpath.exists() {
            let manifest = Manifest::load(&mpath)
                .with_context(|| format!("loading manifest from {}", dir.display()))?;
            return Ok(Runtime {
                manifest,
                engine: engine_for(dir)?,
            });
        }
        Ok(Runtime::native())
    }

    /// The artifact-free native runtime (built-in families).
    pub fn native() -> Runtime {
        Runtime {
            manifest: Manifest::native(),
            engine: Engine::Native,
        }
    }

    /// True when executing through the native Rust engine.
    pub fn is_native(&self) -> bool {
        matches!(self.engine, Engine::Native)
    }

    /// Pre-compile an artifact (warm-up; a no-op on the native engine).
    pub fn warm(&self, name: &str) -> Result<()> {
        match &self.engine {
            Engine::Native => self
                .manifest
                .artifact(name)
                .map(|_| ())
                .ok_or_else(|| anyhow!("unknown artifact '{name}'")),
            #[cfg(feature = "xla")]
            Engine::Xla(e) => e.warm(&self.manifest, name),
        }
    }

    /// Execute an artifact. Inputs are validated against the manifest;
    /// outputs arrive in manifest order.
    pub fn exec(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let spec = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact '{name}' wants {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (v, want)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if v.shape() != want.shape.as_slice() {
                bail!(
                    "artifact '{name}' input {i} ('{}'): shape {:?} != expected {:?}",
                    want.name,
                    v.shape(),
                    want.shape
                );
            }
        }
        match &self.engine {
            Engine::Native => native::exec(&self.manifest, name, inputs),
            #[cfg(feature = "xla")]
            Engine::Xla(e) => e.exec(&self.manifest, name, inputs),
        }
    }

    pub fn artifact_names(&self) -> Vec<String> {
        self.manifest.names()
    }
}

/// Backwards-compatible name from the PJRT-only era; the serving/eval stack
/// is engine-agnostic.
pub type XlaRuntime = Runtime;

/// Default artifact directory: `$ODLRI_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("ODLRI_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_matrix_roundtrip() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let v = Value::from_matrix(&m);
        assert_eq!(v.shape(), &[2, 3]);
        assert_eq!(v.to_matrix().unwrap(), m);
    }

    #[test]
    fn value_flatten_leading() {
        let v = Value::from_vec_f32(vec![2, 3, 4], (0..24).map(|i| i as f32).collect());
        let m = v.to_matrix_2d().unwrap();
        assert_eq!(m.shape(), (6, 4));
        assert_eq!(m.at(5, 3), 23.0);
    }

    #[test]
    fn value_type_checks() {
        let v = Value::from_vec_i32(vec![2], vec![1, 2]);
        assert!(v.f32_data().is_err());
        assert!(v.to_matrix().is_err());
        assert_eq!(v.i32_data().unwrap(), &[1, 2]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn value_shape_checked() {
        Value::from_vec_f32(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn native_runtime_opens_without_artifacts() {
        let rt = Runtime::open(Path::new("definitely/not/a/real/dir")).unwrap();
        assert!(rt.is_native());
        assert!(rt.manifest.family("tl-7s").is_ok());
        assert!(rt.warm("fwd_tl-7s").is_ok());
        assert!(rt.warm("no_such_artifact").is_err());
    }

    #[test]
    fn exec_validates_shapes() {
        let rt = Runtime::native();
        // kernel_fwht wants (128, 128); hand it garbage.
        let bad = Value::from_vec_f32(vec![2, 2], vec![0.0; 4]);
        assert!(rt.exec("kernel_fwht", &[bad]).is_err());
        assert!(rt.exec("nope", &[]).is_err());
    }
}
