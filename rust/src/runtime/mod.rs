//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the only place the `xla` crate is touched. Python never runs at
//! pipeline/eval time — the manifest + HLO text files are the whole
//! interface. Executables are compiled lazily and cached per artifact name.

mod manifest;

pub use manifest::{ArtifactSpec, FamilySpec, IoSpec, Manifest};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::Matrix;

/// A tensor crossing the runtime boundary (f32 or i32, arbitrary rank).
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Value {
    pub fn from_matrix(m: &Matrix) -> Value {
        Value::F32 {
            shape: vec![m.rows(), m.cols()],
            data: m.as_slice().to_vec(),
        }
    }

    pub fn from_vec_f32(shape: Vec<usize>, data: Vec<f32>) -> Value {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape/data mismatch"
        );
        Value::F32 { shape, data }
    }

    pub fn from_vec_i32(shape: Vec<usize>, data: Vec<i32>) -> Value {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape/data mismatch"
        );
        Value::I32 { shape, data }
    }

    pub fn scalar_f32(v: f32) -> Value {
        Value::F32 {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32 { shape, .. } => shape,
            Value::I32 { shape, .. } => shape,
        }
    }

    pub fn f32_data(&self) -> Result<&[f32]> {
        match self {
            Value::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 value"),
        }
    }

    /// Interpret as a 2-D matrix (rank ≤ 2 required; rank-1/0 become 1×n).
    pub fn to_matrix(&self) -> Result<Matrix> {
        let data = self.f32_data()?.to_vec();
        let shape = self.shape();
        let (r, c) = match shape.len() {
            0 => (1, 1),
            1 => (1, shape[0]),
            2 => (shape[0], shape[1]),
            _ => bail!("to_matrix on rank-{} value", shape.len()),
        };
        Ok(Matrix::from_vec(r, c, data))
    }

    /// Flatten leading axes: (a, b, c) → (a·b, c) — used for logits.
    pub fn to_matrix_2d(&self) -> Result<Matrix> {
        let data = self.f32_data()?.to_vec();
        let shape = self.shape();
        if shape.is_empty() {
            return Ok(Matrix::from_vec(1, 1, data));
        }
        let last = *shape.last().unwrap();
        let lead: usize = shape[..shape.len() - 1].iter().product();
        Ok(Matrix::from_vec(lead, last, data))
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Value::F32 { shape, data } => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape literal: {e:?}"))?
            }
            Value::I32 { shape, data } => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape literal: {e:?}"))?
            }
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal) -> Result<Value> {
        let shape = lit.array_shape().map_err(|e| anyhow!("{e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Value::F32 {
                shape: dims,
                data: lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            }),
            xla::ElementType::S32 => Ok(Value::I32 {
                shape: dims,
                data: lit.to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?,
            }),
            other => bail!("unsupported output element type {other:?}"),
        }
    }
}

/// The runtime: PJRT client + artifact directory + executable cache.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl XlaRuntime {
    /// Open the artifact directory (reads `manifest.json`; compiles nothing
    /// yet).
    pub fn open(dir: &Path) -> Result<XlaRuntime> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(XlaRuntime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Compile (or fetch from cache) an artifact by name.
    fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let spec = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile an artifact (warm-up; used by the pipeline so timing
    /// excludes compilation).
    pub fn warm(&self, name: &str) -> Result<()> {
        self.executable(name).map(|_| ())
    }

    /// Execute an artifact. Inputs are validated against the manifest;
    /// outputs arrive in manifest order.
    pub fn exec(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let spec = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
            .clone();
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact '{name}' wants {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (v, want)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if v.shape() != want.shape.as_slice() {
                bail!(
                    "artifact '{name}' input {i} ('{}'): shape {:?} != expected {:?}",
                    want.name,
                    v.shape(),
                    want.shape
                );
            }
        }
        let exe = self.executable(name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|v| v.to_literal())
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow!("untupling {name}: {e:?}"))?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "artifact '{name}' returned {} outputs, manifest says {}",
                parts.len(),
                spec.outputs.len()
            );
        }
        parts.iter().map(Value::from_literal).collect()
    }

    pub fn artifact_names(&self) -> Vec<String> {
        self.manifest.names()
    }
}

/// Default artifact directory: `$ODLRI_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("ODLRI_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_matrix_roundtrip() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let v = Value::from_matrix(&m);
        assert_eq!(v.shape(), &[2, 3]);
        assert_eq!(v.to_matrix().unwrap(), m);
    }

    #[test]
    fn value_flatten_leading() {
        let v = Value::from_vec_f32(vec![2, 3, 4], (0..24).map(|i| i as f32).collect());
        let m = v.to_matrix_2d().unwrap();
        assert_eq!(m.shape(), (6, 4));
        assert_eq!(m.at(5, 3), 23.0);
    }

    #[test]
    fn value_type_checks() {
        let v = Value::from_vec_i32(vec![2], vec![1, 2]);
        assert!(v.f32_data().is_err());
        assert!(v.to_matrix().is_err());
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn value_shape_checked() {
        Value::from_vec_f32(vec![2, 2], vec![1.0]);
    }
}
