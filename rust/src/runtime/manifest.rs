//! Typed view over `artifacts/manifest.json` (written by aot.py).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// One input/output tensor description.
#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One artifact (HLO module) description.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// One model family's parameter layout.
#[derive(Clone, Debug)]
pub struct FamilySpec {
    pub name: String,
    pub params: Vec<(String, Vec<usize>)>,
    pub projections: Vec<String>,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub d_ff: usize,
}

impl FamilySpec {
    /// Index of a parameter by name in the flat layout.
    pub fn param_index(&self, name: &str) -> Result<usize> {
        self.params
            .iter()
            .position(|(n, _)| n == name)
            .ok_or_else(|| anyhow!("no param '{name}' in family {}", self.name))
    }

    pub fn param_shape(&self, name: &str) -> Result<&[usize]> {
        self.params
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| s.as_slice())
            .ok_or_else(|| anyhow!("no param '{name}' in family {}", self.name))
    }

    /// Norm parameters (kept dense; never compressed).
    pub fn is_norm(name: &str) -> bool {
        name.ends_with("ln1") || name.ends_with("ln2") || name.ends_with("ln_f")
    }
}

/// The full manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    artifacts: BTreeMap<String, ArtifactSpec>,
    families: BTreeMap<String, FamilySpec>,
    pub batch: usize,
    pub seq: usize,
    pub fused_rank: usize,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let mut artifacts = BTreeMap::new();
        for (name, art) in j.req("artifacts")?.as_obj()? {
            let io = |key: &str| -> Result<Vec<IoSpec>> {
                art.req(key)?
                    .as_arr()?
                    .iter()
                    .map(|e| {
                        Ok(IoSpec {
                            name: e
                                .get("name")
                                .map(|n| n.as_str().unwrap_or("").to_string())
                                .unwrap_or_default(),
                            shape: e.req("shape")?.as_usize_vec()?,
                            dtype: e
                                .get("dtype")
                                .map(|d| d.as_str().unwrap_or("f32").to_string())
                                .unwrap_or_else(|| "f32".into()),
                        })
                    })
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file: art.req("file")?.as_str()?.to_string(),
                    inputs: io("inputs")?,
                    outputs: io("outputs")?,
                },
            );
        }
        let mut families = BTreeMap::new();
        for (name, fam) in j.req("families")?.as_obj()? {
            let params = fam
                .req("params")?
                .as_arr()?
                .iter()
                .map(|p| {
                    Ok((
                        p.req("name")?.as_str()?.to_string(),
                        p.req("shape")?.as_usize_vec()?,
                    ))
                })
                .collect::<Result<Vec<_>>>()?;
            let projections = fam
                .req("projections")?
                .as_arr()?
                .iter()
                .map(|p| Ok(p.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?;
            families.insert(
                name.clone(),
                FamilySpec {
                    name: name.clone(),
                    params,
                    projections,
                    vocab: fam.req("vocab")?.as_usize()?,
                    d_model: fam.req("d_model")?.as_usize()?,
                    n_layers: fam.req("n_layers")?.as_usize()?,
                    d_ff: fam.req("d_ff")?.as_usize()?,
                },
            );
        }
        Ok(Manifest {
            artifacts,
            families,
            batch: j.req("batch")?.as_usize()?,
            seq: j.req("seq")?.as_usize()?,
            fused_rank: j.req("fused_rank")?.as_usize()?,
        })
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.get(name)
    }

    pub fn family(&self, name: &str) -> Result<&FamilySpec> {
        self.families
            .get(name)
            .ok_or_else(|| anyhow!("unknown model family '{name}'"))
    }

    pub fn family_names(&self) -> Vec<String> {
        self.families.keys().cloned().collect()
    }

    pub fn names(&self) -> Vec<String> {
        self.artifacts.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": {
        "fwd_x": {
          "file": "fwd_x.hlo.txt",
          "inputs": [{"name": "w", "shape": [4, 8], "dtype": "float32"},
                     {"name": "tokens", "shape": [2, 16], "dtype": "int32"}],
          "outputs": [{"shape": [2, 16, 32], "dtype": "float32"}]
        }
      },
      "families": {
        "x": {
          "params": [{"name": "embed", "shape": [32, 8]},
                     {"name": "layer0.wq", "shape": [8, 8]}],
          "projections": ["layer0.wq"],
          "vocab": 32, "d_model": 8, "n_layers": 1, "n_heads": 2,
          "n_kv_heads": 2, "d_ff": 16, "mlp": "swiglu"
        }
      },
      "batch": 2, "seq": 16, "fused_rank": 4
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.batch, 2);
        assert_eq!(m.seq, 16);
        let art = m.artifact("fwd_x").unwrap();
        assert_eq!(art.inputs.len(), 2);
        assert_eq!(art.inputs[1].shape, vec![2, 16]);
        assert_eq!(art.outputs[0].shape, vec![2, 16, 32]);
        let fam = m.family("x").unwrap();
        assert_eq!(fam.param_index("layer0.wq").unwrap(), 1);
        assert_eq!(fam.param_shape("embed").unwrap(), &[32, 8]);
        assert!(fam.param_index("nope").is_err());
    }

    #[test]
    fn norm_detection() {
        assert!(FamilySpec::is_norm("layer3.ln1"));
        assert!(FamilySpec::is_norm("ln_f"));
        assert!(!FamilySpec::is_norm("layer0.wq"));
    }

    #[test]
    fn real_manifest_parses_if_present() {
        let path = std::path::Path::new("artifacts/manifest.json");
        if path.exists() {
            let m = Manifest::load(path).unwrap();
            assert!(m.artifact("fwd_tl-7s").is_some());
            let fam = m.family("tl-7s").unwrap();
            assert_eq!(fam.projections.len(), 7 * fam.n_layers);
        }
    }
}
