//! Typed view over `artifacts/manifest.json` (written by aot.py), plus the
//! built-in family table and the synthesized **native manifest** used when
//! no artifacts are present (the artifact-free fallback executes the same
//! artifact names through [`crate::runtime::native`]).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// One input/output tensor description.
#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl IoSpec {
    fn f32(name: &str, shape: Vec<usize>) -> IoSpec {
        IoSpec {
            name: name.to_string(),
            shape,
            dtype: "float32".into(),
        }
    }

    fn i32(name: &str, shape: Vec<usize>) -> IoSpec {
        IoSpec {
            name: name.to_string(),
            shape,
            dtype: "int32".into(),
        }
    }
}

/// One artifact (HLO module or native-engine entry point) description.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// One model family's parameter layout and architecture knobs.
#[derive(Clone, Debug)]
pub struct FamilySpec {
    pub name: String,
    pub params: Vec<(String, Vec<usize>)>,
    pub projections: Vec<String>,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    /// `"swiglu"` (silu(gate)·up) or `"geglu"` (gelu(gate)·up, Gemma-style).
    pub mlp: String,
    pub rope_theta: f32,
}

impl FamilySpec {
    /// Index of a parameter by name in the flat layout.
    pub fn param_index(&self, name: &str) -> Result<usize> {
        self.params
            .iter()
            .position(|(n, _)| n == name)
            .ok_or_else(|| anyhow!("no param '{name}' in family {}", self.name))
    }

    pub fn param_shape(&self, name: &str) -> Result<&[usize]> {
        self.params
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| s.as_slice())
            .ok_or_else(|| anyhow!("no param '{name}' in family {}", self.name))
    }

    /// Norm parameters (kept dense; never compressed).
    pub fn is_norm(name: &str) -> bool {
        name.ends_with("ln1") || name.ends_with("ln2") || name.ends_with("ln_f")
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim()
    }

    pub fn is_geglu(&self) -> bool {
        self.mlp == "geglu"
    }

    /// The five built-in tiny families (mirrors `python/compile/model.py`).
    pub fn builtin_names() -> [&'static str; 5] {
        ["tl-7s", "tl-13s", "tl3-8s", "tm-7s", "tg-2s"]
    }

    /// Construct a built-in family spec by name.
    pub fn builtin(name: &str) -> Option<FamilySpec> {
        let (vocab, d_model, n_layers, n_heads, n_kv_heads, d_ff, mlp) = match name {
            "tl-7s" => (256, 128, 4, 4, 4, 352, "swiglu"),
            "tl-13s" => (256, 192, 5, 6, 6, 512, "swiglu"),
            "tl3-8s" => (384, 128, 4, 4, 2, 384, "swiglu"),
            "tm-7s" => (256, 128, 4, 4, 2, 448, "swiglu"),
            "tg-2s" => (256, 96, 3, 4, 4, 320, "geglu"),
            _ => return None,
        };
        Some(FamilySpec::build(
            name, vocab, d_model, n_layers, n_heads, n_kv_heads, d_ff, mlp,
        ))
    }

    /// Build a family spec with the canonical Llama-style parameter layout
    /// (embed, per-layer [ln1 wq wk wv wo ln2 wgate wup wdown], ln_f,
    /// unembed) — the exact order every artifact expects.
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        name: &str,
        vocab: usize,
        d_model: usize,
        n_layers: usize,
        n_heads: usize,
        n_kv_heads: usize,
        d_ff: usize,
        mlp: &str,
    ) -> FamilySpec {
        assert!(n_heads > 0 && d_model % n_heads == 0, "d_model % n_heads");
        assert!(
            n_kv_heads > 0 && n_heads % n_kv_heads == 0,
            "n_heads % n_kv_heads"
        );
        let head_dim = d_model / n_heads;
        let kv_dim = n_kv_heads * head_dim;
        let mut params: Vec<(String, Vec<usize>)> =
            vec![("embed".into(), vec![vocab, d_model])];
        let mut projections = Vec::with_capacity(7 * n_layers);
        for i in 0..n_layers {
            let p = format!("layer{i}.");
            params.push((format!("{p}ln1"), vec![d_model]));
            params.push((format!("{p}wq"), vec![d_model, d_model]));
            params.push((format!("{p}wk"), vec![kv_dim, d_model]));
            params.push((format!("{p}wv"), vec![kv_dim, d_model]));
            params.push((format!("{p}wo"), vec![d_model, d_model]));
            params.push((format!("{p}ln2"), vec![d_model]));
            params.push((format!("{p}wgate"), vec![d_ff, d_model]));
            params.push((format!("{p}wup"), vec![d_ff, d_model]));
            params.push((format!("{p}wdown"), vec![d_model, d_ff]));
            for w in ["wq", "wk", "wv", "wo", "wgate", "wup", "wdown"] {
                projections.push(format!("{p}{w}"));
            }
        }
        params.push(("ln_f".into(), vec![d_model]));
        params.push(("unembed".into(), vec![vocab, d_model]));
        FamilySpec {
            name: name.to_string(),
            params,
            projections,
            vocab,
            d_model,
            n_layers,
            d_ff,
            n_heads,
            n_kv_heads,
            mlp: mlp.to_string(),
            rope_theta: 10000.0,
        }
    }
}

/// The full manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    artifacts: BTreeMap<String, ArtifactSpec>,
    families: BTreeMap<String, FamilySpec>,
    pub batch: usize,
    pub seq: usize,
    pub fused_rank: usize,
}

/// Batch/sequence/fused-rank the native engine mirrors from aot.py.
pub const NATIVE_BATCH: usize = 8;
pub const NATIVE_SEQ: usize = 96;
pub const NATIVE_FUSED_RANK: usize = 32;

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let mut artifacts = BTreeMap::new();
        for (name, art) in j.req("artifacts")?.as_obj()? {
            let io = |key: &str| -> Result<Vec<IoSpec>> {
                art.req(key)?
                    .as_arr()?
                    .iter()
                    .map(|e| {
                        Ok(IoSpec {
                            name: e
                                .get("name")
                                .map(|n| n.as_str().unwrap_or("").to_string())
                                .unwrap_or_default(),
                            shape: e.req("shape")?.as_usize_vec()?,
                            dtype: e
                                .get("dtype")
                                .map(|d| d.as_str().unwrap_or("f32").to_string())
                                .unwrap_or_else(|| "f32".into()),
                        })
                    })
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file: art.req("file")?.as_str()?.to_string(),
                    inputs: io("inputs")?,
                    outputs: io("outputs")?,
                },
            );
        }
        let mut families = BTreeMap::new();
        for (name, fam) in j.req("families")?.as_obj()? {
            let params = fam
                .req("params")?
                .as_arr()?
                .iter()
                .map(|p| {
                    Ok((
                        p.req("name")?.as_str()?.to_string(),
                        p.req("shape")?.as_usize_vec()?,
                    ))
                })
                .collect::<Result<Vec<_>>>()?;
            let projections = fam
                .req("projections")?
                .as_arr()?
                .iter()
                .map(|p| Ok(p.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?;
            let d_model = fam.req("d_model")?.as_usize()?;
            let n_heads = match fam.get("n_heads") {
                Some(v) => v.as_usize()?,
                None => (d_model / 32).max(1),
            };
            let n_kv_heads = match fam.get("n_kv_heads") {
                Some(v) => v.as_usize()?,
                None => n_heads,
            };
            let mlp = fam
                .get("mlp")
                .and_then(|v| v.as_str().ok())
                .unwrap_or("swiglu")
                .to_string();
            let rope_theta = match fam.get("rope_theta") {
                Some(v) => v.as_f64()? as f32,
                None => 10000.0,
            };
            families.insert(
                name.clone(),
                FamilySpec {
                    name: name.clone(),
                    params,
                    projections,
                    vocab: fam.req("vocab")?.as_usize()?,
                    d_model,
                    n_layers: fam.req("n_layers")?.as_usize()?,
                    d_ff: fam.req("d_ff")?.as_usize()?,
                    n_heads,
                    n_kv_heads,
                    mlp,
                    rope_theta,
                },
            );
        }
        Ok(Manifest {
            artifacts,
            families,
            batch: j.req("batch")?.as_usize()?,
            seq: j.req("seq")?.as_usize()?,
            fused_rank: j.req("fused_rank")?.as_usize()?,
        })
    }

    /// Synthesize the manifest the native engine serves when no artifact
    /// directory exists: all five built-in families with `fwd_*`,
    /// `fwd_fused_*`, `train_*`, `capture_*` entry points plus the three
    /// standalone kernels — identical names, shapes, and semantics to the
    /// AOT-lowered artifacts.
    pub fn native() -> Manifest {
        let (batch, seq, fused_rank) = (NATIVE_BATCH, NATIVE_SEQ, NATIVE_FUSED_RANK);
        let mut artifacts = BTreeMap::new();
        let mut families = BTreeMap::new();
        for name in FamilySpec::builtin_names() {
            // lint:allow(hot-path-panic) iterating builtin_names(): every name resolves by construction
            let fam = FamilySpec::builtin(name).expect("builtin family");
            let pspecs: Vec<IoSpec> = fam
                .params
                .iter()
                .map(|(n, s)| IoSpec::f32(n, s.clone()))
                .collect();
            let bs = batch * seq;

            // fwd: params + tokens → logits
            let mut inputs = pspecs.clone();
            inputs.push(IoSpec::i32("tokens", vec![batch, seq]));
            artifacts.insert(
                format!("fwd_{name}"),
                ArtifactSpec {
                    file: "<native>".into(),
                    inputs,
                    outputs: vec![IoSpec::f32("logits", vec![batch, seq, fam.vocab])],
                },
            );

            // fwd_fused: params + (Q, L, R) per projection + tokens → logits
            let mut inputs = pspecs.clone();
            for proj in &fam.projections {
                // lint:allow(hot-path-panic) fam.projections is a subset of fam.params by FamilySpec construction
                let shape = fam.param_shape(proj).expect("projection shape");
                inputs.push(IoSpec::f32(&format!("{proj}.q"), shape.to_vec()));
                inputs.push(IoSpec::f32(
                    &format!("{proj}.l"),
                    vec![shape[0], fused_rank],
                ));
                inputs.push(IoSpec::f32(
                    &format!("{proj}.r"),
                    vec![fused_rank, shape[1]],
                ));
            }
            inputs.push(IoSpec::i32("tokens", vec![batch, seq]));
            artifacts.insert(
                format!("fwd_fused_{name}"),
                ArtifactSpec {
                    file: "<native>".into(),
                    inputs,
                    outputs: vec![IoSpec::f32("logits", vec![batch, seq, fam.vocab])],
                },
            );

            // train: params + m + v + step + tokens → params' + m' + v' + loss
            let mut inputs = pspecs.clone();
            for suffix in ["m", "v"] {
                for (n, s) in &fam.params {
                    inputs.push(IoSpec::f32(&format!("{n}.{suffix}"), s.clone()));
                }
            }
            inputs.push(IoSpec::f32("step", vec![]));
            inputs.push(IoSpec::i32("tokens", vec![batch, seq + 1]));
            let mut outputs = pspecs.clone();
            for suffix in ["m", "v"] {
                for (n, s) in &fam.params {
                    outputs.push(IoSpec::f32(&format!("{n}.{suffix}"), s.clone()));
                }
            }
            outputs.push(IoSpec::f32("loss", vec![]));
            artifacts.insert(
                format!("train_{name}"),
                ArtifactSpec {
                    file: "<native>".into(),
                    inputs,
                    outputs,
                },
            );

            // capture: params + tokens → 4 activation matrices per layer,
            // each (in_dim, batch·seq) with columns as samples.
            let mut inputs = pspecs.clone();
            inputs.push(IoSpec::i32("tokens", vec![batch, seq]));
            let mut outputs = Vec::with_capacity(4 * fam.n_layers);
            for layer in 0..fam.n_layers {
                outputs.push(IoSpec::f32(
                    &format!("layer{layer}.attn_in"),
                    vec![fam.d_model, bs],
                ));
                outputs.push(IoSpec::f32(
                    &format!("layer{layer}.attn_ctx"),
                    vec![fam.d_model, bs],
                ));
                outputs.push(IoSpec::f32(
                    &format!("layer{layer}.mlp_in"),
                    vec![fam.d_model, bs],
                ));
                outputs.push(IoSpec::f32(
                    &format!("layer{layer}.mlp_mid"),
                    vec![fam.d_ff, bs],
                ));
            }
            artifacts.insert(
                format!("capture_{name}"),
                ArtifactSpec {
                    file: "<native>".into(),
                    inputs,
                    outputs,
                },
            );

            families.insert(name.to_string(), fam);
        }

        // Standalone kernels (shapes match the Pallas lowerings).
        artifacts.insert(
            "kernel_quantize".into(),
            ArtifactSpec {
                file: "<native>".into(),
                inputs: vec![IoSpec::f32("w", vec![128, 128])],
                outputs: vec![IoSpec::f32("deq", vec![128, 128])],
            },
        );
        artifacts.insert(
            "kernel_fused_qlr".into(),
            ArtifactSpec {
                file: "<native>".into(),
                inputs: vec![
                    IoSpec::f32("q", vec![128, 128]),
                    IoSpec::f32("l", vec![128, 32]),
                    IoSpec::f32("r", vec![32, 128]),
                    IoSpec::f32("x", vec![128, 16]),
                ],
                outputs: vec![IoSpec::f32("y", vec![128, 16])],
            },
        );
        artifacts.insert(
            "kernel_fwht".into(),
            ArtifactSpec {
                file: "<native>".into(),
                inputs: vec![IoSpec::f32("w", vec![128, 128])],
                outputs: vec![IoSpec::f32("hw", vec![128, 128])],
            },
        );

        Manifest {
            artifacts,
            families,
            batch,
            seq,
            fused_rank,
        }
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.get(name)
    }

    pub fn family(&self, name: &str) -> Result<&FamilySpec> {
        self.families
            .get(name)
            .ok_or_else(|| anyhow!("unknown model family '{name}'"))
    }

    pub fn family_names(&self) -> Vec<String> {
        self.families.keys().cloned().collect()
    }

    pub fn names(&self) -> Vec<String> {
        self.artifacts.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": {
        "fwd_x": {
          "file": "fwd_x.hlo.txt",
          "inputs": [{"name": "w", "shape": [4, 8], "dtype": "float32"},
                     {"name": "tokens", "shape": [2, 16], "dtype": "int32"}],
          "outputs": [{"shape": [2, 16, 32], "dtype": "float32"}]
        }
      },
      "families": {
        "x": {
          "params": [{"name": "embed", "shape": [32, 8]},
                     {"name": "layer0.wq", "shape": [8, 8]}],
          "projections": ["layer0.wq"],
          "vocab": 32, "d_model": 8, "n_layers": 1, "n_heads": 2,
          "n_kv_heads": 2, "d_ff": 16, "mlp": "swiglu"
        }
      },
      "batch": 2, "seq": 16, "fused_rank": 4
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.batch, 2);
        assert_eq!(m.seq, 16);
        let art = m.artifact("fwd_x").unwrap();
        assert_eq!(art.inputs.len(), 2);
        assert_eq!(art.inputs[1].shape, vec![2, 16]);
        assert_eq!(art.outputs[0].shape, vec![2, 16, 32]);
        let fam = m.family("x").unwrap();
        assert_eq!(fam.param_index("layer0.wq").unwrap(), 1);
        assert_eq!(fam.param_shape("embed").unwrap(), &[32, 8]);
        assert!(fam.param_index("nope").is_err());
        // Architecture knobs parsed (with graceful defaults elsewhere).
        assert_eq!(fam.n_heads, 2);
        assert_eq!(fam.n_kv_heads, 2);
        assert_eq!(fam.mlp, "swiglu");
        assert_eq!(fam.head_dim(), 4);
    }

    #[test]
    fn norm_detection() {
        assert!(FamilySpec::is_norm("layer3.ln1"));
        assert!(FamilySpec::is_norm("ln_f"));
        assert!(!FamilySpec::is_norm("layer0.wq"));
    }

    #[test]
    fn builtin_families_match_model_py() {
        for name in FamilySpec::builtin_names() {
            let fam = FamilySpec::builtin(name).unwrap();
            assert_eq!(fam.projections.len(), 7 * fam.n_layers, "{name}");
            assert_eq!(fam.params.len(), 3 + 9 * fam.n_layers, "{name}");
            assert_eq!(fam.d_model % fam.n_heads, 0, "{name}");
            assert_eq!(fam.n_heads % fam.n_kv_heads, 0, "{name}");
        }
        let tl = FamilySpec::builtin("tl-7s").unwrap();
        assert_eq!(tl.param_shape("layer0.wgate").unwrap(), &[352, 128]);
        assert_eq!(tl.param_shape("layer3.wdown").unwrap(), &[128, 352]);
        let tl3 = FamilySpec::builtin("tl3-8s").unwrap();
        assert_eq!(tl3.kv_dim(), 64); // GQA: 2 kv-heads × head_dim 32
        assert_eq!(tl3.param_shape("layer0.wk").unwrap(), &[64, 128]);
        let tg = FamilySpec::builtin("tg-2s").unwrap();
        assert!(tg.is_geglu());
        assert!(FamilySpec::builtin("nope").is_none());
    }

    #[test]
    fn native_manifest_is_complete() {
        let m = Manifest::native();
        assert_eq!(m.batch, NATIVE_BATCH);
        assert_eq!(m.seq, NATIVE_SEQ);
        assert_eq!(m.fused_rank, NATIVE_FUSED_RANK);
        for name in FamilySpec::builtin_names() {
            for prefix in ["fwd", "fwd_fused", "train", "capture"] {
                assert!(
                    m.artifact(&format!("{prefix}_{name}")).is_some(),
                    "missing {prefix}_{name}"
                );
            }
        }
        let fam = m.family("tl-7s").unwrap();
        let fwd = m.artifact("fwd_tl-7s").unwrap();
        assert_eq!(fwd.inputs.len(), fam.params.len() + 1);
        assert_eq!(fwd.outputs[0].shape, vec![8, 96, 256]);
        let train = m.artifact("train_tl-7s").unwrap();
        assert_eq!(train.inputs.len(), 3 * fam.params.len() + 2);
        assert_eq!(train.outputs.len(), 3 * fam.params.len() + 1);
        assert_eq!(train.inputs.last().unwrap().shape, vec![8, 97]);
        let cap = m.artifact("capture_tl-7s").unwrap();
        assert_eq!(cap.outputs.len(), 4 * fam.n_layers);
        assert_eq!(cap.outputs[0].shape, vec![128, 8 * 96]);
        assert_eq!(cap.outputs[3].shape, vec![352, 8 * 96]);
        let fused = m.artifact("fwd_fused_tl-7s").unwrap();
        assert_eq!(
            fused.inputs.len(),
            fam.params.len() + 3 * fam.projections.len() + 1
        );
        assert!(m.artifact("kernel_fused_qlr").is_some());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        let path = std::path::Path::new("artifacts/manifest.json");
        if path.exists() {
            let m = Manifest::load(path).unwrap();
            assert!(m.artifact("fwd_tl-7s").is_some());
            let fam = m.family("tl-7s").unwrap();
            assert_eq!(fam.projections.len(), 7 * fam.n_layers);
        }
    }
}
