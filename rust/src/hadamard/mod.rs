//! Fast Walsh–Hadamard transform and QuIP#-style randomized incoherence
//! processing.
//!
//! CALDERA (and QuIP/QuIP#) pre-multiplies `W ← H_m D_m W D_n H_n` with
//! random sign diagonals `D` and (scaled) Hadamard matrices `H` so that the
//! transformed weights are *incoherent* — no single entry dominates — which
//! makes lattice/scalar quantization dramatically better behaved. The
//! Hessian transforms covariantly: `H' = H_n D_n H D_n H_n` (right-side
//! transform only, since X enters as WX).
//!
//! Non-power-of-two dimensions use the largest power-of-two block strategy:
//! the dimension is split into pow2 segments, each transformed independently
//! (standard practice in QuIP# for e.g. 11008-dim MLP axes).

use crate::tensor::Matrix;
use crate::util::rng::Pcg64;

/// In-place FWHT of a length-2^k slice, normalized by 1/√n so the transform
/// is orthonormal (involutive).
pub fn fwht_normalized(x: &mut [f32]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "fwht needs power-of-two length");
    let mut h = 1;
    while h < n {
        for i in (0..n).step_by(h * 2) {
            for j in i..i + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
        }
        h *= 2;
    }
    let scale = 1.0 / (n as f32).sqrt();
    for v in x.iter_mut() {
        *v *= scale;
    }
}

/// Largest power of two ≤ n.
fn pow2_floor(n: usize) -> usize {
    if n == 0 {
        0
    } else {
        1 << (usize::BITS - 1 - n.leading_zeros())
    }
}

/// Split a dimension into power-of-two segments (greedy largest-first).
pub fn pow2_segments(n: usize) -> Vec<(usize, usize)> {
    let mut segs = Vec::new();
    let mut start = 0;
    let mut rem = n;
    while rem > 0 {
        let p = pow2_floor(rem);
        segs.push((start, p));
        start += p;
        rem -= p;
    }
    segs
}

/// Blocked orthonormal Hadamard applied along each row of M (i.e. M ← M H_n^T
/// where H_n is the blocked transform; H is symmetric so transposition is
/// moot per block).
pub fn fwht_rows(m: &mut Matrix) {
    let segs = pow2_segments(m.cols());
    for i in 0..m.rows() {
        let row = m.row_mut(i);
        for &(s, len) in &segs {
            fwht_normalized(&mut row[s..s + len]);
        }
    }
}

/// Blocked orthonormal Hadamard applied along each column of M (M ← H_m M).
pub fn fwht_cols(m: &mut Matrix) {
    let segs = pow2_segments(m.rows());
    let mut buf = vec![0f32; m.rows()];
    for j in 0..m.cols() {
        for i in 0..m.rows() {
            buf[i] = m.at(i, j);
        }
        for &(s, len) in &segs {
            fwht_normalized(&mut buf[s..s + len]);
        }
        for i in 0..m.rows() {
            *m.at_mut(i, j) = buf[i];
        }
    }
}

/// A two-sided randomized Hadamard incoherence transform: remembers the sign
/// diagonals so it can be inverted exactly.
#[derive(Clone, Debug)]
pub struct Incoherence {
    pub left_signs: Vec<f32>,  // D_m, length = rows of W
    pub right_signs: Vec<f32>, // D_n, length = cols of W
}

impl Incoherence {
    pub fn new(rows: usize, cols: usize, rng: &mut Pcg64) -> Incoherence {
        Incoherence {
            left_signs: (0..rows).map(|_| rng.sign()).collect(),
            right_signs: (0..cols).map(|_| rng.sign()).collect(),
        }
    }

    /// W̃ = H_m D_m W D_n H_n
    pub fn apply(&self, w: &Matrix) -> Matrix {
        let mut t = w.mul_diag_left(&self.left_signs);
        t = t.mul_diag_right(&self.right_signs);
        fwht_cols(&mut t);
        fwht_rows(&mut t);
        t
    }

    /// W = D_m H_m W̃ H_n D_n (exact inverse: H orthonormal+symmetric per
    /// block, D² = I).
    pub fn unapply(&self, wt: &Matrix) -> Matrix {
        let mut t = wt.clone();
        fwht_cols(&mut t);
        fwht_rows(&mut t);
        t = t.mul_diag_left(&self.left_signs);
        t.mul_diag_right(&self.right_signs)
    }

    /// Transform the Hessian covariantly: if W̃ = … W D_n H_n then the
    /// activation side transforms as X̃ = H_n D_n X, so
    /// H̃ = X̃ X̃^T = H_n D_n H D_n H_n.
    pub fn apply_hessian(&self, h: &Matrix) -> Matrix {
        let mut t = h.mul_diag_left(&self.right_signs);
        t = t.mul_diag_right(&self.right_signs);
        fwht_cols(&mut t);
        fwht_rows(&mut t);
        t
    }

    /// Transform activations: X̃ = H_n D_n X (X is n x d with n = W's cols).
    pub fn apply_acts(&self, x: &Matrix) -> Matrix {
        let mut t = x.mul_diag_left(&self.right_signs);
        fwht_cols(&mut t);
        t
    }

    /// Forward-transform low-rank factors from the original basis into the
    /// incoherent basis: L̃ = H_m D_m L ; R̃ = R D_n H_n (so that
    /// L̃ R̃ = apply(L R)).
    pub fn apply_left(&self, l: &Matrix) -> Matrix {
        let mut t = l.mul_diag_left(&self.left_signs);
        fwht_cols(&mut t);
        t
    }

    pub fn apply_right(&self, r: &Matrix) -> Matrix {
        let mut t = r.mul_diag_right(&self.right_signs);
        fwht_rows(&mut t);
        t
    }

    /// Inverse-transform the low-rank factors found in the incoherent basis
    /// back to the original basis:
    /// L = D_m H_m L̃ ;  R = R̃ H_n D_n.
    pub fn unapply_left(&self, lt: &Matrix) -> Matrix {
        let mut t = lt.clone();
        fwht_cols(&mut t);
        t.mul_diag_left(&self.left_signs)
    }

    pub fn unapply_right(&self, rt: &Matrix) -> Matrix {
        let mut t = rt.clone();
        fwht_rows(&mut t);
        t.mul_diag_right(&self.right_signs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fwht_matches_explicit_h2() {
        let mut x = vec![1.0f32, 2.0];
        fwht_normalized(&mut x);
        let s = 1.0 / 2f32.sqrt();
        assert!((x[0] - 3.0 * s).abs() < 1e-6);
        assert!((x[1] - (-1.0) * s).abs() < 1e-6);
    }

    #[test]
    fn fwht_is_involutive() {
        let mut rng = Pcg64::new(60, 1);
        let mut x: Vec<f32> = (0..64).map(|_| rng.normal_f32()).collect();
        let orig = x.clone();
        fwht_normalized(&mut x);
        fwht_normalized(&mut x);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn fwht_preserves_norm() {
        let mut rng = Pcg64::new(61, 1);
        let mut x: Vec<f32> = (0..128).map(|_| rng.normal_f32()).collect();
        let n0: f32 = x.iter().map(|v| v * v).sum();
        fwht_normalized(&mut x);
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-3 * n0);
    }

    #[test]
    fn pow2_segments_cover() {
        for n in [1usize, 2, 3, 7, 8, 12, 100, 344] {
            let segs = pow2_segments(n);
            let total: usize = segs.iter().map(|&(_, l)| l).sum();
            assert_eq!(total, n);
            assert!(segs.iter().all(|&(_, l)| l.is_power_of_two()));
            // Contiguous.
            let mut pos = 0;
            for &(s, l) in &segs {
                assert_eq!(s, pos);
                pos += l;
            }
        }
    }

    #[test]
    fn incoherence_roundtrips() {
        let mut rng = Pcg64::new(62, 1);
        for &(m, n) in &[(16usize, 32usize), (24, 40), (13, 13)] {
            let w = Matrix::randn(m, n, 1.0, &mut rng);
            let inc = Incoherence::new(m, n, &mut rng);
            let wt = inc.apply(&w);
            let back = inc.unapply(&wt);
            assert!(back.rel_err(&w) < 1e-4, "{m}x{n}");
        }
    }

    #[test]
    fn incoherence_preserves_product_wx() {
        // (W̃)(X̃) = H_m D_m (W X): the transformed product is an orthogonal
        // transform of WX, so ‖W̃ X̃‖ = ‖W X‖.
        let mut rng = Pcg64::new(63, 1);
        let w = Matrix::randn(16, 32, 1.0, &mut rng);
        let x = Matrix::randn(32, 20, 1.0, &mut rng);
        let inc = Incoherence::new(16, 32, &mut rng);
        let wt = inc.apply(&w);
        let xt = inc.apply_acts(&x);
        let p1 = wt.dot(&xt).frob_norm();
        let p2 = w.dot(&x).frob_norm();
        assert!((p1 - p2).abs() < 1e-2 * p2, "{p1} vs {p2}");
    }

    #[test]
    fn hessian_transform_consistent_with_acts() {
        let mut rng = Pcg64::new(64, 1);
        let x = Matrix::randn(32, 50, 1.0, &mut rng);
        let h = x.dot_t(&x);
        let inc = Incoherence::new(8, 32, &mut rng);
        let ht_direct = inc.apply_hessian(&h);
        let xt = inc.apply_acts(&x);
        let ht_from_x = xt.dot_t(&xt);
        assert!(ht_direct.rel_err(&ht_from_x) < 1e-3);
    }

    #[test]
    fn incoherence_reduces_peak_to_frob_ratio() {
        // A spiky matrix becomes incoherent: max|w| / ‖W‖_F shrinks.
        let mut w = Matrix::zeros(64, 64);
        *w.at_mut(3, 5) = 100.0;
        *w.at_mut(10, 60) = -80.0;
        for i in 0..64 {
            *w.at_mut(i, i) += 0.1;
        }
        let mut rng = Pcg64::new(65, 1);
        let inc = Incoherence::new(64, 64, &mut rng);
        let wt = inc.apply(&w);
        let ratio_before = w.abs_max() / w.frob_norm();
        let ratio_after = wt.abs_max() / wt.frob_norm();
        assert!(
            ratio_after < ratio_before * 0.25,
            "before={ratio_before} after={ratio_after}"
        );
    }

    #[test]
    fn lr_apply_matches_matrix_transform() {
        // apply(L R) == apply_left(L) @ apply_right(R).
        let mut rng = Pcg64::new(67, 1);
        let l = Matrix::randn(16, 4, 1.0, &mut rng);
        let r = Matrix::randn(4, 32, 1.0, &mut rng);
        let inc = Incoherence::new(16, 32, &mut rng);
        let direct = inc.apply(&l.dot(&r));
        let via_factors = inc.apply_left(&l).dot(&inc.apply_right(&r));
        assert!(via_factors.rel_err(&direct) < 1e-4);
        // unapply_left ∘ apply_left = id.
        assert!(inc.unapply_left(&inc.apply_left(&l)).rel_err(&l) < 1e-5);
        assert!(inc.unapply_right(&inc.apply_right(&r)).rel_err(&r) < 1e-5);
    }

    #[test]
    fn lr_unapply_consistent() {
        // If W̃ ≈ L̃ R̃ then W ≈ (D H L̃)(R̃ H D).
        let mut rng = Pcg64::new(66, 1);
        let l = Matrix::randn(16, 4, 1.0, &mut rng);
        let r = Matrix::randn(4, 32, 1.0, &mut rng);
        let wt = l.dot(&r);
        let inc = Incoherence::new(16, 32, &mut rng);
        let w = inc.unapply(&wt);
        let lb = inc.unapply_left(&l);
        let rb = inc.unapply_right(&r);
        assert!(lb.dot(&rb).rel_err(&w) < 1e-4);
    }
}
