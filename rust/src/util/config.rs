//! Tiny INI/TOML-subset config parser for `configs/*.toml`.
//!
//! Supports `[section]` headers, `key = value` lines (string, int, float,
//! bool, and `[a, b, c]` lists of ints/strings), `#` comments. That is the
//! entire surface the experiment configs need; nested tables are spelled as
//! `section.sub` headers.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed config: flat map from `section.key` → raw value.
#[derive(Clone, Debug, Default)]
pub struct Config {
    vals: BTreeMap<String, Value>,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    IntList(Vec<i64>),
    StrList(Vec<String>),
}

impl Config {
    pub fn parse(text: &str) -> Result<Config> {
        let mut vals = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let value = parse_value(v.trim())
                .with_context(|| format!("line {}: bad value for '{key}'", lineno + 1))?;
            vals.insert(key, value);
        }
        Ok(Config { vals })
    }

    pub fn load(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Config::parse(&text)
    }

    /// Apply `key=value` overrides (CLI `--set section.key=value`).
    pub fn set_override(&mut self, spec: &str) -> Result<()> {
        let (k, v) = spec
            .split_once('=')
            .ok_or_else(|| anyhow!("override must be key=value, got '{spec}'"))?;
        self.vals.insert(k.trim().to_string(), parse_value(v.trim())?);
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.vals.get(key)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        match self.vals.get(key) {
            Some(Value::Str(s)) => s.clone(),
            Some(v) => format!("{v:?}"),
            None => default.to_string(),
        }
    }

    pub fn int(&self, key: &str, default: i64) -> i64 {
        match self.vals.get(key) {
            Some(Value::Int(v)) => *v,
            Some(Value::Float(v)) => *v as i64,
            _ => default,
        }
    }

    pub fn float(&self, key: &str, default: f64) -> f64 {
        match self.vals.get(key) {
            Some(Value::Float(v)) => *v,
            Some(Value::Int(v)) => *v as f64,
            _ => default,
        }
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        match self.vals.get(key) {
            Some(Value::Bool(b)) => *b,
            _ => default,
        }
    }

    pub fn int_list(&self, key: &str, default: &[i64]) -> Vec<i64> {
        match self.vals.get(key) {
            Some(Value::IntList(v)) => v.clone(),
            Some(Value::Int(v)) => vec![*v],
            _ => default.to_vec(),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.vals.keys()
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| anyhow!("unterminated string"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("unterminated list"))?;
        let items: Vec<&str> = inner
            .split(',')
            .map(|x| x.trim())
            .filter(|x| !x.is_empty())
            .collect();
        if items.iter().all(|x| x.parse::<i64>().is_ok()) {
            return Ok(Value::IntList(
                items.iter().map(|x| x.parse::<i64>().unwrap()).collect(),
            ));
        }
        return Ok(Value::StrList(
            items
                .iter()
                .map(|x| x.trim_matches('"').to_string())
                .collect(),
        ));
    }
    if let Ok(v) = s.parse::<i64>() {
        return Ok(Value::Int(v));
    }
    if let Ok(v) = s.parse::<f64>() {
        return Ok(Value::Float(v));
    }
    // Bare word: treat as string (model names etc.).
    Ok(Value::Str(s.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# compression config
model = "tl-7s"

[quant]
bits = 2            # Q bits
scheme = e8
group = 64

[lowrank]
ranks = [64, 128, 256]
lr_bits = 4
lplr_iters = 10

[joint]
outer_iters = 15
hadamard = true
reg = 1e-4
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str("model", ""), "tl-7s");
        assert_eq!(c.int("quant.bits", 0), 2);
        assert_eq!(c.str("quant.scheme", ""), "e8");
        assert_eq!(c.int_list("lowrank.ranks", &[]), vec![64, 128, 256]);
        assert!(c.bool("joint.hadamard", false));
        assert!((c.float("joint.reg", 0.0) - 1e-4).abs() < 1e-12);
    }

    #[test]
    fn defaults_apply() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.int("missing.key", 7), 7);
        assert_eq!(c.str("missing", "dflt"), "dflt");
    }

    #[test]
    fn overrides() {
        let mut c = Config::parse(SAMPLE).unwrap();
        c.set_override("quant.bits=3").unwrap();
        c.set_override("model=\"tm-7s\"").unwrap();
        assert_eq!(c.int("quant.bits", 0), 3);
        assert_eq!(c.str("model", ""), "tm-7s");
        assert!(c.set_override("nonsense").is_err());
    }

    #[test]
    fn comments_inside_strings_kept() {
        let c = Config::parse("k = \"a#b\"").unwrap();
        assert_eq!(c.str("k", ""), "a#b");
    }
}
