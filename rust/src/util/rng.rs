//! Deterministic pseudo-random number generation (PCG64 + SplitMix64).
//!
//! Every stochastic component of the pipeline (corpus generation, synthetic
//! calibration activations, randomized SVD sketches, Hadamard sign vectors,
//! weight init fallback) draws from a [`Pcg64`] seeded from an explicit
//! `(seed, stream)` pair, so whole-pipeline runs are bit-reproducible and
//! independent of worker-thread scheduling.

/// SplitMix64 — used to expand a small seed into PCG state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG-XSH-RR 64/32 with 64-bit output assembled from two draws.
///
/// Small, fast, statistically solid for simulation purposes; the stream
/// (increment) parameter gives us cheap independent substreams per job.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Distinct streams are
    /// statistically independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = seed;
        let init_state = splitmix64(&mut sm);
        let mut rng = Pcg64 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(init_state);
        rng.next_u32();
        rng
    }

    /// Derive a child generator from a string label (stable across runs).
    pub fn fork(&mut self, label: &str) -> Self {
        let h = crate::util::fnv1a(label.as_bytes());
        Pcg64::new(self.next_u64() ^ h, h | 1)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n). Uses rejection sampling to avoid modulo bias.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (caches the second value).
    pub fn normal(&mut self) -> f64 {
        // Marsaglia polar method — no trig, numerically friendly.
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Standard normal as f32.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with i.i.d. N(0, sigma^2).
    pub fn fill_normal(&mut self, buf: &mut [f32], sigma: f32) {
        for x in buf.iter_mut() {
            *x = self.normal_f32() * sigma;
        }
    }

    /// Random sign in {-1.0, +1.0}.
    #[inline]
    pub fn sign(&mut self) -> f32 {
        if self.next_u32() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        // Partial Fisher–Yates: only the first k positions are needed.
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed_and_stream() {
        let mut a = Pcg64::new(42, 7);
        let mut b = Pcg64::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg64::new(1, 1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut r = Pcg64::new(3, 3);
        let mut counts = [0usize; 5];
        let n = 50_000;
        for _ in 0..n {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            let p = c as f64 / n as f64;
            assert!((p - 0.2).abs() < 0.02, "p={p}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(9, 1);
        let n = 100_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::new(5, 5);
        let idx = r.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(sorted.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(11, 2);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_is_stable() {
        let mut a = Pcg64::new(42, 7);
        let mut b = Pcg64::new(42, 7);
        let mut fa = a.fork("job.layer0.key");
        let mut fb = b.fork("job.layer0.key");
        assert_eq!(fa.next_u64(), fb.next_u64());
    }
}
