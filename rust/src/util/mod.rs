//! Small std-only utilities: deterministic RNG, JSON, config parsing,
//! formatting helpers. These exist because the offline vendor set contains
//! only `xla` + `anyhow`; everything else is built from std.

pub mod config;
pub mod json;
pub mod rng;

/// Format a byte count as a human-readable string (`1.5 MiB`).
pub fn human_bytes(n: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a duration in seconds with adaptive precision.
pub fn human_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2} s")
    } else {
        format!("{:.1} min", s / 60.0)
    }
}

/// FNV-1a 64-bit hash — stable across runs/platforms (used to derive
/// per-job RNG streams from names).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(12), "12 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn human_secs_ranges() {
        assert!(human_secs(2e-9).ends_with("ns"));
        assert!(human_secs(2e-5).ends_with("µs"));
        assert!(human_secs(0.02).ends_with("ms"));
        assert!(human_secs(3.0).ends_with(" s"));
        assert!(human_secs(300.0).ends_with("min"));
    }

    #[test]
    fn fnv1a_stable() {
        // Known FNV-1a vectors.
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
        assert_ne!(fnv1a(b"layer0.key"), fnv1a(b"layer0.query"));
    }
}
