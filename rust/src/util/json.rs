//! Minimal JSON reader/writer (no external crates).
//!
//! Used for the AOT artifact manifest written by `python/compile/aot.py`
//! and for experiment result files. Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP (not needed for our manifests).

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects use a BTreeMap so serialization is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("set() on non-object Json");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow!("missing key '{key}' in JSON object"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(v) => Ok(*v),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let v = self.as_f64()?;
        if v < 0.0 || v.fract() != 0.0 {
            bail!("expected non-negative integer, got {v}");
        }
        Ok(v as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// Convenience: array of usizes (shape lists in the manifest).
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|j| j.as_usize()).collect()
    }

    pub fn from_strs(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&v| Json::Num(v)).collect())
    }

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {} in JSON", p.i);
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 1e15 {
                    write!(f, "{}", *v as i64)
                } else {
                    write!(f, "{v}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(xs) => {
                write!(f, "[")?;
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected '{}' at byte {}, found '{}'",
                c as char,
                self.i,
                self.b[self.i] as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character '{}' at byte {}", c as char, self.i),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        c => bail!("bad escape '\\{}'", c as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // Multi-byte UTF-8: copy the remaining continuation bytes.
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.i - 1;
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut j = Json::obj();
        j.set("name", Json::Str("fwd_tl-7s".into()))
            .set("params", Json::Num(17.0))
            .set(
                "shape",
                Json::Arr(vec![Json::Num(8.0), Json::Num(128.0)]),
            )
            .set("ok", Json::Bool(true));
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, j);
        assert_eq!(back.req("name").unwrap().as_str().unwrap(), "fwd_tl-7s");
        assert_eq!(
            back.req("shape").unwrap().as_usize_vec().unwrap(),
            vec![8, 128]
        );
    }

    #[test]
    fn parse_nested_and_escapes() {
        let j = Json::parse(r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\nyA"}, "d": null}"#)
            .unwrap();
        assert_eq!(j.req("a").unwrap().as_arr().unwrap()[2].as_f64().unwrap(), -300.0);
        assert_eq!(
            j.req("b").unwrap().req("c").unwrap().as_str().unwrap(),
            "x\nyA"
        );
        assert_eq!(j.req("d").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_unicode_passthrough() {
        let j = Json::parse("{\"k\": \"héllo — ✓\"}").unwrap();
        assert_eq!(j.req("k").unwrap().as_str().unwrap(), "héllo — ✓");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(1.5).to_string(), "1.5");
    }
}
