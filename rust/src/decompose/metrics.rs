//! Per-iteration metrics of the joint optimization — exactly the quantities
//! the paper's figures/tables track:
//!
//! * `quant_scale`   — the quantizer's chosen scale (Figure 2/4),
//! * `act_err`       — ‖(W − Q − LR)X‖²_F / ‖WX‖²_F (Figure 3/5),
//! * `q_norm`        — ‖QX‖/‖WX‖ (Table 1/12/13),
//! * `lr_norm`       — ‖LRX‖/‖WX‖ (Table 1/12/13).
//!
//! All norms are computed through the Hessian (‖AX‖² = tr(A H Aᵀ)), so the
//! trace is exact w.r.t. the calibration set without storing X.

use crate::lowrank::LrPair;
use crate::tensor::Matrix;

/// ‖A X‖_F via the Hessian: sqrt(tr(A H Aᵀ)).
pub fn h_norm(a: &Matrix, h: &Matrix) -> f64 {
    let ah = a.dot(h);
    let v: f64 = ah
        .as_slice()
        .iter()
        .zip(a.as_slice())
        .map(|(&p, &q)| p as f64 * q as f64)
        .sum();
    v.max(0.0).sqrt()
}

/// Metric traces over the optimization. Index 0 is the *initialization*
/// state (Q = 0, LR = L₀R₀); index t ≥ 1 is after outer iteration t.
#[derive(Clone, Debug, Default)]
pub struct DecompMetrics {
    pub quant_scale: Vec<f32>,
    pub act_err: Vec<f64>,
    pub q_norm: Vec<f64>,
    pub lr_norm: Vec<f64>,
}

/// One row of the trace (for reporting).
#[derive(Clone, Copy, Debug)]
pub struct IterationMetrics {
    pub iter: usize,
    pub quant_scale: f32,
    pub act_err: f64,
    pub q_norm: f64,
    pub lr_norm: f64,
}

impl DecompMetrics {
    pub fn new() -> DecompMetrics {
        DecompMetrics::default()
    }

    pub fn record_init(&mut self, w: &Matrix, lr: &LrPair, h: &Matrix, wx_norm: f64) {
        let lr_prod = lr.product();
        let resid = w.sub(&lr_prod);
        let e = h_norm(&resid, h);
        self.quant_scale.push(0.0);
        self.act_err.push((e / wx_norm.max(1e-30)).powi(2));
        self.q_norm.push(0.0);
        self.lr_norm.push(h_norm(&lr_prod, h) / wx_norm.max(1e-30));
    }

    pub fn record_iter(
        &mut self,
        w: &Matrix,
        q_deq: &Matrix,
        q_scale: f32,
        lr: &LrPair,
        h: &Matrix,
        wx_norm: f64,
    ) {
        let lr_prod = lr.product();
        let resid = w.sub(q_deq).sub(&lr_prod);
        let e = h_norm(&resid, h);
        self.quant_scale.push(q_scale);
        self.act_err.push((e / wx_norm.max(1e-30)).powi(2));
        self.q_norm.push(h_norm(q_deq, h) / wx_norm.max(1e-30));
        self.lr_norm.push(h_norm(&lr_prod, h) / wx_norm.max(1e-30));
    }

    pub fn iterations(&self) -> impl Iterator<Item = IterationMetrics> + '_ {
        (0..self.act_err.len()).map(move |i| IterationMetrics {
            iter: i,
            quant_scale: self.quant_scale[i],
            act_err: self.act_err[i],
            q_norm: self.q_norm[i],
            lr_norm: self.lr_norm[i],
        })
    }

    pub fn last(&self) -> Option<IterationMetrics> {
        self.iterations().last()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn h_norm_matches_direct_product() {
        let mut rng = Pcg64::new(160, 1);
        let a = Matrix::randn(8, 12, 1.0, &mut rng);
        let x = Matrix::randn(12, 40, 1.0, &mut rng);
        let h = x.dot_t(&x);
        let direct = a.dot(&x).frob_norm() as f64;
        let via_h = h_norm(&a, &h);
        assert!((direct - via_h).abs() < 1e-2 * direct);
    }

    #[test]
    fn record_traces_align() {
        let mut rng = Pcg64::new(161, 1);
        let w = Matrix::randn(6, 8, 1.0, &mut rng);
        let x = Matrix::randn(8, 20, 1.0, &mut rng);
        let h = x.dot_t(&x);
        let wx = h_norm(&w, &h);
        let mut m = DecompMetrics::new();
        let lr = LrPair::zeros(6, 8, 2);
        m.record_init(&w, &lr, &h, wx);
        // Zero init: act_err = 1 (nothing explained), lr_norm = 0.
        assert!((m.act_err[0] - 1.0).abs() < 1e-6);
        assert_eq!(m.lr_norm[0], 0.0);
        m.record_iter(&w, &w, 0.5, &lr, &h, wx);
        // Perfect Q: error 0, q_norm 1.
        assert!(m.act_err[1] < 1e-9);
        assert!((m.q_norm[1] - 1.0).abs() < 1e-5);
        assert_eq!(m.quant_scale[1], 0.5);
        assert_eq!(m.iterations().count(), 2);
        assert_eq!(m.last().unwrap().iter, 1);
    }
}
