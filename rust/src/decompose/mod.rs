//! The paper's contribution: joint `W ≈ Q + L·R` optimization (CALDERA,
//! Algorithm 1) with pluggable low-rank **initializers** — including
//! Outlier-Driven Low-Rank Initialization (ODLRI, §3.2 / App. B.1).
//!
//! ```text
//! L₀,R₀ ← Initialize            (Zero | LRApprox(W) | ODLRI)
//! for t in 1..=T:
//!     Q_t   ← Quantize(W − L_{t−1} R_{t−1})        (LDLQ, act-aware)
//!     L_t,R_t ← LRApprox(W − Q_t)                  (whitened SVD [+ LPLR])
//! ```
//!
//! Per-iteration metrics (quantization scale, normalized activation-aware
//! error, ‖QX‖/‖WX‖, ‖LRX‖/‖WX‖) feed the Figure 2/3 and Table 1/8/12/13
//! reproductions.

mod initializer;
mod metrics;

pub use initializer::{odlri_init, Initializer};
pub use metrics::{h_norm, DecompMetrics, IterationMetrics};

use crate::hadamard::Incoherence;
use crate::hessian::Hessian;
use crate::lowrank::{lr_approx, LowRankConfig, LrPair};
use crate::quant::{PackedMatrix, Quantizer};
use crate::tensor::Matrix;
use crate::util::rng::Pcg64;

/// Configuration of the joint optimizer (CALDERA defaults from the paper's
/// App. A: 15 outer iterations, Hadamard incoherence on, update order Q→LR).
#[derive(Clone, Debug)]
pub struct JointConfig {
    pub outer_iters: usize,
    pub lowrank: LowRankConfig,
    /// Randomized Hadamard incoherence pre-processing (QuIP#).
    pub hadamard: bool,
    /// Hessian regularization λ (applied once, before the loop).
    pub reg: f32,
    /// k-schedule numerator for ODLRI (see [`Initializer::odlri_k`]).
    pub seed: u64,
}

impl Default for JointConfig {
    fn default() -> Self {
        JointConfig {
            outer_iters: 15,
            lowrank: LowRankConfig::default(),
            hadamard: true,
            reg: 1e-4,
            seed: 0,
        }
    }
}

/// Result of a joint decomposition.
#[derive(Clone, Debug)]
pub struct Decomposition {
    /// Quantize-dequantized Q (original basis).
    pub q: Matrix,
    /// The quantizer's native packed codes for the same `Q` — rotated-basis
    /// grid codes plus the Hadamard sign metadata when incoherence
    /// processing was on. `q_packed.unpack()` reproduces `q` bit-exactly,
    /// so the fused deployment container serves exactly this decomposition.
    pub q_packed: PackedMatrix,
    /// Low-rank factors (original basis).
    pub lr: LrPair,
    /// Per-iteration metric trace.
    pub metrics: DecompMetrics,
}

impl Decomposition {
    /// Ŵ = Q + L R.
    pub fn reconstruct(&self) -> Matrix {
        self.q.add(&self.lr.product())
    }
}

/// The joint optimizer. Holds the quantizer; everything else arrives per
/// call so one optimizer can be shared across worker threads.
pub struct JointOptimizer<'a> {
    pub quantizer: &'a dyn Quantizer,
    pub config: JointConfig,
}

impl<'a> JointOptimizer<'a> {
    pub fn new(quantizer: &'a dyn Quantizer, config: JointConfig) -> Self {
        JointOptimizer { quantizer, config }
    }

    /// Run Algorithm 1 on `w` with calibration Hessian `hess`.
    ///
    /// All internal math happens in the incoherent basis when
    /// `config.hadamard` (the CALDERA default); outputs are rotated back so
    /// `q + l·r ≈ w` in the original basis and metrics are measured against
    /// the *original* activations.
    pub fn run(&self, w: &Matrix, hess: &Hessian, init: &Initializer) -> Decomposition {
        let cfg = &self.config;
        let mut rng = Pcg64::new(cfg.seed ^ 0x0D15_71A1, 1);

        // Initialization happens in the ORIGINAL basis: ODLRI's top-k
        // diagonal selection needs the un-smeared Hessian (the whole point
        // of the Hadamard incoherence transform is to flatten exactly the
        // outlier structure ODLRI keys on). The factors are then rotated
        // into the working basis, which is exact: L̃R̃ = apply(LR).
        let mut lr = init.initialize(w, hess, &cfg.lowrank, &mut rng);

        // Basis setup.
        let inc = cfg
            .hadamard
            .then(|| Incoherence::new(w.rows(), w.cols(), &mut rng));
        let (wt, h_reg) = match &inc {
            Some(inc) => {
                let wt = inc.apply(w);
                let ht = inc.apply_hessian(&hess.regularized(cfg.reg));
                lr = LrPair {
                    l: inc.apply_left(&lr.l),
                    r: inc.apply_right(&lr.r),
                };
                (wt, ht)
            }
            None => (w.clone(), hess.regularized(cfg.reg)),
        };

        // Metrics are measured in the working basis: ‖QX̃‖ relates to the
        // original ‖QX‖ by the orthogonal left factor, so ratios match.
        let mut metrics = DecompMetrics::new();
        let wx_norm = metrics::h_norm(&wt, &h_reg);
        metrics.record_init(&wt, &lr, &h_reg, wx_norm);

        let mut q_deq = Matrix::zeros(w.rows(), w.cols());
        let mut q_packed: Option<PackedMatrix> = None;
        for t in 0..cfg.outer_iters {
            // Q-step: quantize the residual left by LR. Only the final
            // iteration's Q ships — encode native codes just for it.
            let resid_q = wt.sub(&lr.product());
            let q_scale;
            if t + 1 == cfg.outer_iters {
                let out = self.quantizer.quantize_with_hessian(&resid_q, &h_reg);
                q_deq = out.deq;
                q_scale = out.scale;
                q_packed = Some(out.packed);
            } else {
                let (deq, scale) = self.quantizer.quantize_with_hessian_dense(&resid_q, &h_reg);
                q_deq = deq;
                q_scale = scale;
            }
            // LR-step: re-fit the factors to what Q leaves behind.
            // rank 0 = quantization-only baseline (QuIP# row of Table 9):
            // LR stays identically zero and the loop is a fixed point after
            // the first iteration.
            if cfg.lowrank.rank > 0 {
                let resid_lr = wt.sub(&q_deq);
                lr = lr_approx(&resid_lr, &h_reg, &cfg.lowrank, &mut rng);
            }
            metrics.record_iter(&wt, &q_deq, q_scale, &lr, &h_reg, wx_norm);
        }

        // Degenerate outer_iters == 0: Q stays zero; an all-zero uniform
        // pack decodes to exact zeros.
        let q_packed =
            q_packed.unwrap_or_else(|| PackedMatrix::pack(&q_deq, 8, w.cols().max(1)));

        // Rotate back to the original basis. The packed codes stay in the
        // working basis: when incoherence is on they carry the sign
        // diagonals instead, so their decode replays this exact un-rotation
        // bit-for-bit.
        let (q_out, lr_out, q_packed) = match &inc {
            Some(inc) => (
                inc.unapply(&q_deq),
                LrPair {
                    l: inc.unapply_left(&lr.l),
                    r: inc.unapply_right(&lr.r),
                },
                q_packed.with_rotation(inc.left_signs.clone(), inc.right_signs.clone()),
            ),
            None => (q_deq, lr, q_packed),
        };
        Decomposition {
            q: q_out,
            q_packed,
            lr: lr_out,
            metrics,
        }
    }
}

/// Average bits/weight of a decomposition under the paper's bookkeeping:
/// Q at `q_bits` (+overhead) over m·n weights plus (m+n)·r factor entries
/// at `lr_bits`.
pub fn avg_bits(
    rows: usize,
    cols: usize,
    rank: usize,
    q_bits_with_overhead: f64,
    lr_bits: u32,
) -> f64 {
    let lr_bits = lr_bits.min(16) as f64;
    q_bits_with_overhead + (rows + cols) as f64 * rank as f64 * lr_bits / (rows * cols) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::E8Lattice;
    use crate::testing;

    fn setup(
        m: usize,
        n: usize,
        outliers: usize,
        seed: u64,
    ) -> (Matrix, Hessian, Matrix) {
        let mut rng = Pcg64::new(seed, 1);
        let w = Matrix::randn(m, n, 1.0, &mut rng);
        let (x, _) = testing::gen_outlier_acts(&mut rng, n, 2 * n, outliers);
        let h = Hessian::from_acts(&x);
        (w, h, x)
    }

    fn act_err(w: &Matrix, d: &Decomposition, x: &Matrix) -> f32 {
        let num = w.sub(&d.reconstruct()).dot(x).frob_norm();
        let den = w.dot(x).frob_norm();
        num / den
    }

    #[test]
    fn joint_opt_reduces_error_over_iterations() {
        let (w, h, _x) = setup(32, 48, 3, 200);
        let quant = E8Lattice::new(2);
        let cfg = JointConfig {
            outer_iters: 8,
            lowrank: LowRankConfig {
                rank: 8,
                lr_bits: 16,
                ..Default::default()
            },
            ..Default::default()
        };
        let opt = JointOptimizer::new(&quant, cfg);
        let d = opt.run(&w, &h, &Initializer::Zero);
        let errs = &d.metrics.act_err;
        assert!(errs.len() == 9); // init + 8 iters
        // Final error below the first post-quantization error.
        assert!(errs[errs.len() - 1] <= errs[1] * 1.05);
        assert!(errs[errs.len() - 1] < 1.0);
    }

    #[test]
    fn reconstruction_in_original_basis() {
        // With/without Hadamard must land in the same ballpark and both
        // approximate W (sanity that the basis rotation round-trips).
        let (w, h, x) = setup(16, 32, 2, 201);
        let quant = E8Lattice::new(2);
        for hadamard in [false, true] {
            let cfg = JointConfig {
                outer_iters: 4,
                hadamard,
                lowrank: LowRankConfig {
                    rank: 6,
                    lr_bits: 16,
                    ..Default::default()
                },
                ..Default::default()
            };
            let d = JointOptimizer::new(&quant, cfg).run(&w, &h, &Initializer::Zero);
            let e = act_err(&w, &d, &x);
            assert!(e < 0.5, "hadamard={hadamard} err={e}");
        }
    }

    #[test]
    fn zero_init_assigns_reconstruction_role_to_q() {
        // Table 1 shape: with zero init, ‖QX‖/‖WX‖ ≈ 1 and ‖LRX‖/‖WX‖ small
        // at the first iteration, and roles persist.
        let (w, h, _x) = setup(32, 64, 3, 202);
        let quant = E8Lattice::new(2);
        let cfg = JointConfig {
            outer_iters: 6,
            lowrank: LowRankConfig {
                rank: 8,
                lr_bits: 16,
                ..Default::default()
            },
            ..Default::default()
        };
        let d = JointOptimizer::new(&quant, cfg).run(&w, &h, &Initializer::Zero);
        let qn = &d.metrics.q_norm;
        let lrn = &d.metrics.lr_norm;
        assert!(qn[1] > 0.8, "first-iter ‖QX‖/‖WX‖ = {}", qn[1]);
        assert!(lrn[1] < 0.4, "first-iter ‖LRX‖/‖WX‖ = {}", lrn[1]);
        assert!(qn.last().unwrap() > &0.6, "Q role must persist");
    }

    #[test]
    fn lrapprox_init_assigns_reconstruction_role_to_lr() {
        let (w, h, _x) = setup(32, 64, 3, 203);
        let quant = E8Lattice::new(2);
        let cfg = JointConfig {
            outer_iters: 6,
            lowrank: LowRankConfig {
                rank: 24, // enough capacity to actually hold W's mass
                lr_bits: 16,
                ..Default::default()
            },
            ..Default::default()
        };
        let d = JointOptimizer::new(&quant, cfg).run(&w, &h, &Initializer::LrApproxW);
        let qn = &d.metrics.q_norm;
        let lrn = &d.metrics.lr_norm;
        assert!(
            lrn[1] > qn[1],
            "LR must dominate after LRApprox init: lr={} q={}",
            lrn[1],
            qn[1]
        );
    }

    #[test]
    fn odlri_lowers_quant_scale_vs_zero_init() {
        // Figure 2 shape: ODLRI's quantization scale must be below zero-init
        // at every iteration when the activations carry strong outliers.
        let (w, h, _x) = setup(48, 64, 4, 204);
        let quant = E8Lattice::new(2);
        let mk = |init: &Initializer| {
            let cfg = JointConfig {
                outer_iters: 5,
                lowrank: LowRankConfig {
                    rank: 16,
                    lr_bits: 16,
                    ..Default::default()
                },
                ..Default::default()
            };
            JointOptimizer::new(&quant, cfg).run(&w, &h, init)
        };
        let d_zero = mk(&Initializer::Zero);
        let d_odlri = mk(&Initializer::Odlri { k: 4 });
        let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
        let s_zero = mean(&d_zero.metrics.quant_scale);
        let s_odlri = mean(&d_odlri.metrics.quant_scale);
        assert!(
            s_odlri < s_zero,
            "odlri scale {s_odlri} !< zero-init scale {s_zero}"
        );
    }

    #[test]
    fn odlri_lowers_act_error() {
        // Figure 3 shape (aggregate over seeds to be robust).
        let mut wins = 0;
        let trials = 5;
        for t in 0..trials {
            let (w, h, x) = setup(40, 64, 4, 300 + t);
            let quant = E8Lattice::new(2);
            let mk = |init: &Initializer| {
                let cfg = JointConfig {
                    outer_iters: 5,
                    lowrank: LowRankConfig {
                        rank: 12,
                        lr_bits: 16,
                        ..Default::default()
                    },
                    ..Default::default()
                };
                JointOptimizer::new(&quant, cfg).run(&w, &h, init)
            };
            let e_zero = act_err(&w, &mk(&Initializer::Zero), &x);
            let e_odlri = act_err(&w, &mk(&Initializer::Odlri { k: 4 }), &x);
            if e_odlri < e_zero {
                wins += 1;
            }
        }
        assert!(wins >= 4, "ODLRI won only {wins}/{trials}");
    }

    /// The deployment contract: for every quantizer scheme, with and
    /// without Hadamard incoherence (the LDLQ-rotated case), the native
    /// packed codes decode to the pipeline's `Q` with **zero** error.
    #[test]
    fn packed_codes_reproduce_pipeline_q_bit_exactly() {
        for scheme in ["uniform", "e8", "mxint"] {
            for hadamard in [false, true] {
                let (w, h, _x) = setup(24, 40, 2, 205);
                let quant = crate::quant::make_quantizer(scheme, 2, 8).unwrap();
                let cfg = JointConfig {
                    outer_iters: 2,
                    hadamard,
                    lowrank: LowRankConfig {
                        rank: 4,
                        lr_bits: 16,
                        ..Default::default()
                    },
                    ..Default::default()
                };
                let d = JointOptimizer::new(quant.as_ref(), cfg).run(&w, &h, &Initializer::Zero);
                assert_eq!(d.q_packed.rows, 24);
                assert_eq!(d.q_packed.cols, 40);
                assert_eq!(d.q_packed.rotation.is_some(), hadamard);
                let diff = d.q_packed.unpack().max_abs_diff(&d.q);
                assert_eq!(diff, 0.0, "{scheme} hadamard={hadamard}: diff {diff}");
            }
        }
    }

    #[test]
    fn avg_bits_matches_paper_examples() {
        // Llama2-7B rank-64 ≈ 2.1 avg bits (Table 2): 4096² matrix,
        // 2-bit Q, 4-bit LR → 2 + 8192·64·4/4096² = 2.125.
        let b = avg_bits(4096, 4096, 64, 2.0, 4);
        assert!((b - 2.125).abs() < 0.01, "b={b}");
        // rank-256 → 2.5 (paper rounds to 2.4 including their packing).
        let b = avg_bits(4096, 4096, 256, 2.0, 4);
        assert!((b - 2.5).abs() < 0.01);
    }
}
