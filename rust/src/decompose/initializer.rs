//! Low-rank initialization strategies — the paper's central object of study.
//!
//! * `Zero` — CALDERA's default (quantize-first): `L₀ = R₀ = 0`, so `Q`
//!   becomes the primary representation and `LR` a residual corrector.
//! * `LrApproxW` — low-rank-first (LQ-LoRA-style): `L₀R₀ ≈ W` via whitened
//!   SVD, so `LR` holds the weight mass and `Q` quantizes residuals.
//! * `Odlri` — **Outlier-Driven Low-Rank Initialization** (§3.2, App. B.1):
//!   factorize `W` against the *outlier-restricted* Hessian `H_o` so the
//!   low-rank component explicitly captures the activation-sensitive
//!   (salient) weights, leaving a smooth residual for `Q`.

use crate::hessian::Hessian;
use crate::linalg::{cholesky_jittered, solve_lower_transpose, truncated_svd};
use crate::lowrank::{whitened_svd_lr, LowRankConfig, LrPair};
use crate::tensor::Matrix;
use crate::util::rng::Pcg64;

/// LR initialization strategy for Algorithm 1.
#[derive(Clone, Debug, PartialEq)]
pub enum Initializer {
    /// L₀ = R₀ = 0 (CALDERA default).
    Zero,
    /// L₀R₀ = LRApprox(W) against the full Hessian.
    LrApproxW,
    /// ODLRI with `k` outlier channels (k < r per App. B.2).
    Odlri { k: usize },
}

impl Initializer {
    pub fn name(&self) -> String {
        match self {
            Initializer::Zero => "zero".into(),
            Initializer::LrApproxW => "lrapprox".into(),
            Initializer::Odlri { k } => format!("odlri-k{k}"),
        }
    }

    /// The paper's rank-dependent outlier-count schedule (App. B.2):
    /// `k = p·n` with p = 0.1% (r=64), 0.2% (r=128), 0.4% (r=256) on
    /// n = 4096 — i.e. exactly `k = r/16` at every setting (4096·0.001·
    /// (r/64) = r/16). We adopt the scale-free form so the schedule
    /// transfers to our smaller matrices, clamped to [1, min(r, n)].
    pub fn odlri_k(rank: usize, n: usize) -> usize {
        (rank / 16).clamp(1, rank.max(1).min(n))
    }

    /// Produce L₀, R₀ for weight `w` under Hessian `hess` (both already in
    /// the working basis; the restricted top-k selection happens on this
    /// Hessian's diagonal).
    pub fn initialize(
        &self,
        w: &Matrix,
        hess: &Hessian,
        cfg: &LowRankConfig,
        rng: &mut Pcg64,
    ) -> LrPair {
        match self {
            Initializer::Zero => LrPair::zeros(w.rows(), w.cols(), cfg.rank),
            Initializer::LrApproxW => {
                whitened_svd_lr(w, &hess.regularized(cfg.reg), cfg.rank, rng)
            }
            Initializer::Odlri { k } => odlri_init(w, hess, cfg.rank, *k, rng),
        }
    }
}

/// ODLRI (App. B.1):
///
/// 1. 𝓘 ← indices of the top-k diagonal entries of H (outlier channels).
/// 2. `H_o` ← H restricted to 𝓘×𝓘 (Eq. 1); factor its dense k×k block
///    `H[𝓘,𝓘] = S_o S_oᵀ` (Cholesky; eigen-sqrt fallback if deficient).
/// 3. SVD(W[:, 𝓘] S_o), truncate to rank r → `L₀ = U √Σ`,
///    `R₀[:, 𝓘] = √Σ Vᵀ S_o⁻¹`, zero elsewhere.
///
/// Because `H_o` has rank ≤ k < r, the SVD has at most k non-zero singular
/// values: `L₀R₀` spends its capacity *entirely* on the outlier-sensitive
/// weight directions — the role assignment that defines the method.
pub fn odlri_init(
    w: &Matrix,
    hess: &Hessian,
    rank: usize,
    k: usize,
    rng: &mut Pcg64,
) -> LrPair {
    let (m, n) = w.shape();
    let k = k.max(1).min(n);
    let idx = hess.topk_diag(k);

    // Dense k×k outlier block and its square-root factor.
    let sub = hess.submatrix(&idx);
    let s_o = match cholesky_jittered(&sub, 1e-6) {
        Ok((c, _)) => c,
        Err(_) => crate::linalg::psd_sqrt(&sub),
    };

    // Whitened outlier-column weights: (m × k).
    let w_o = w.gather_cols(&idx);
    let b = w_o.dot(&s_o);
    let svd = truncated_svd(&b, rank.min(k), rng);
    let (l, rt) = svd.split_lr(); // rt = √Σ Vᵀ : (r' × k)

    // R₀ columns on 𝓘: rt S_o⁻¹ (solve instead of explicit inverse).
    let r_cols_t = solve_lower_transpose(&s_o, &rt.transpose()); // (k × r')
    let rprime = l.cols();

    // Embed into full-rank factors (rank r total; unused directions zero —
    // they get filled by the first LRApprox step of the joint loop).
    let mut l_full = Matrix::zeros(m, rank);
    for i in 0..m {
        for j in 0..rprime {
            *l_full.at_mut(i, j) = l.at(i, j);
        }
    }
    let mut r_full = Matrix::zeros(rank, n);
    for (col_pos, &orig_col) in idx.iter().enumerate() {
        for j in 0..rprime {
            *r_full.at_mut(j, orig_col) = r_cols_t.at(col_pos, j);
        }
    }
    LrPair {
        l: l_full,
        r: r_full,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;

    fn outlier_setup(
        m: usize,
        n: usize,
        k: usize,
        seed: u64,
    ) -> (Matrix, Hessian, Matrix, Vec<usize>) {
        let mut rng = Pcg64::new(seed, 1);
        let w = Matrix::randn(m, n, 1.0, &mut rng);
        let (x, idx) = testing::gen_outlier_acts(&mut rng, n, 2 * n, k);
        let h = Hessian::from_acts(&x);
        (w, h, x, idx)
    }

    #[test]
    fn k_schedule_matches_appendix_b2() {
        // Llama2-7B key proj: n = 4096, r=256 → k ≈ 16.
        assert_eq!(Initializer::odlri_k(256, 4096), 16);
        // r=64 → 0.1% of 4096 ≈ 4.
        assert_eq!(Initializer::odlri_k(64, 4096), 4);
        // r=128 → 8.
        assert_eq!(Initializer::odlri_k(128, 4096), 8);
        // Tiny n floors at 1 and caps at r.
        assert!(Initializer::odlri_k(4, 16) >= 1);
        assert!(Initializer::odlri_k(4, 1_000_000) <= 4);
    }

    #[test]
    fn odlri_captures_salient_weights() {
        // Table 8 shape: ‖L₀R₀ X_o‖/‖W X_o‖ ≈ 1 (salient weights absorbed)
        // while the residual on X_o is tiny.
        testing::quick("odlri-salient", |rng| {
            let n = 48;
            let m = 32;
            let k = 3;
            let w = testing::gen_matrix(rng, m, n);
            let (x, idx) = testing::gen_outlier_acts(rng, n, 2 * n, k);
            let h = Hessian::from_acts(&x);
            let lr = odlri_init(&w, &h, 12, k, rng);
            let xo = x.mask_rows(&idx);
            let w_xo = w.dot(&xo).frob_norm();
            let lr_xo = lr.l.dot(&lr.r.dot(&xo)).frob_norm();
            let resid_xo = w.sub(&lr.product()).dot(&xo).frob_norm();
            assert!(
                lr_xo > 0.95 * w_xo && resid_xo < 0.1 * w_xo,
                "lr/w = {}, resid/w = {}",
                lr_xo / w_xo,
                resid_xo / w_xo
            );
        });
    }

    #[test]
    fn odlri_r_supported_only_on_outlier_columns() {
        let (w, h, _x, idx) = outlier_setup(24, 40, 4, 210);
        let mut rng = Pcg64::new(211, 1);
        let lr = odlri_init(&w, &h, 10, 4, &mut rng);
        for j in 0..40 {
            if !idx.contains(&j) {
                for t in 0..10 {
                    assert_eq!(lr.r.at(t, j), 0.0, "R non-zero off-support at col {j}");
                }
            }
        }
    }

    #[test]
    fn odlri_rank_capacity_is_k() {
        // With k < r, at most k directions are used (the rest zero).
        let (w, h, _x, _idx) = outlier_setup(16, 32, 3, 212);
        let mut rng = Pcg64::new(213, 1);
        let lr = odlri_init(&w, &h, 8, 3, &mut rng);
        // Columns 3..8 of L must be zero.
        for j in 3..8 {
            for i in 0..16 {
                assert_eq!(lr.l.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn odlri_beats_full_h_on_outlier_reconstruction() {
        // App. B.3 / Table 8: restricting to H_o approximates W X_o better
        // than whitening against the full H at the same rank budget.
        let mut wins = 0;
        let trials = 10;
        for t in 0..trials {
            let (w, h, x, idx) = outlier_setup(32, 48, 3, 400 + t);
            let mut rng = Pcg64::new(401, t);
            let r = 8;
            let with_ho = odlri_init(&w, &h, r, 3, &mut rng);
            let with_h = whitened_svd_lr(&w, &h.regularized(1e-4), r, &mut rng);
            let xo = x.mask_rows(&idx);
            let e_ho = w.sub(&with_ho.product()).dot(&xo).frob_norm();
            let e_h = w.sub(&with_h.product()).dot(&xo).frob_norm();
            if e_ho < e_h {
                wins += 1;
            }
        }
        assert!(wins >= 8, "H_o won only {wins}/{trials}");
    }

    #[test]
    fn initializer_names_stable() {
        assert_eq!(Initializer::Zero.name(), "zero");
        assert_eq!(Initializer::LrApproxW.name(), "lrapprox");
        assert_eq!(Initializer::Odlri { k: 16 }.name(), "odlri-k16");
    }

    #[test]
    fn zero_init_is_zero() {
        let (w, h, _x, _i) = outlier_setup(8, 12, 2, 214);
        let mut rng = Pcg64::new(215, 1);
        let cfg = LowRankConfig {
            rank: 4,
            ..Default::default()
        };
        let lr = Initializer::Zero.initialize(&w, &h, &cfg, &mut rng);
        assert_eq!(lr.product(), Matrix::zeros(8, 12));
    }

    #[test]
    fn degenerate_k_handled() {
        let (w, h, _x, _i) = outlier_setup(8, 12, 2, 216);
        let mut rng = Pcg64::new(217, 1);
        // k = 0 clamps to 1; k > n clamps to n.
        let a = odlri_init(&w, &h, 4, 0, &mut rng);
        assert!(a.product().is_finite());
        let b = odlri_init(&w, &h, 4, 100, &mut rng);
        assert!(b.product().is_finite());
    }
}
