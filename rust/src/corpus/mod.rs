//! Synthetic grammar corpus + proxy evaluation tasks.
//!
//! Stands in for the paper's WikiText-2 / C4 / lm-eval-harness suite
//! (DESIGN.md §2): a deterministic templated language whose rules are
//! learnable by the tiny model families, two held-out splits with different
//! template mixes (`wiki-sim`, `c4-sim`) for perplexity, and five two-choice
//! tasks (`wino-sim`, `rte-sim`, `piqa-sim`, `arce-sim`, `arcc-sim`) scored
//! by sequence log-probability exactly like lm-eval's multiple-choice path.
//!
//! Tokenization is byte-level (every model family has vocab ≥ 256).

use crate::util::rng::Pcg64;

pub const ANIMALS: &[&str] = &["cat", "dog", "fox", "owl", "bee", "elk"];
pub const OBJECTS: &[&str] = &["box", "cup", "key", "map", "pot", "rug"];
pub const NAMES: &[&str] = &["ana", "ben", "kim", "lee", "mia", "sam"];
pub const VERBS: &[&str] = &["sees", "takes", "likes", "finds", "holds"];
/// Adjective pairs (synonym-ish, antonym): rule substrate for rte-sim.
pub const ADJ_PAIRS: &[(&str, &str, &str)] = &[
    ("big", "large", "small"),
    ("old", "aged", "new"),
    ("fast", "quick", "slow"),
    ("warm", "hot", "cold"),
];
/// Tool → action map: rule substrate for piqa-sim.
pub const TOOL_ACTIONS: &[(&str, &str, &str)] = &[
    ("pen", "write", "pour"),
    ("cup", "drink", "dig"),
    ("key", "open", "eat"),
    ("map", "travel", "bake"),
    ("broom", "sweep", "sing"),
];
pub const NUMBERS: &[&str] = &[
    "zero", "one", "two", "three", "four", "five", "six", "seven", "eight",
    "nine",
];

/// Corpus splits. Train is a balanced mix; the eval splits use different
/// template proportions so they behave like two distributions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    WikiSim,
    C4Sim,
}

impl Split {
    pub fn name(&self) -> &'static str {
        match self {
            Split::Train => "train",
            Split::WikiSim => "wiki-sim",
            Split::C4Sim => "c4-sim",
        }
    }

    /// Template mix weights: (simple, wino, rte, piqa, arith).
    fn mix(&self) -> [u32; 5] {
        match self {
            Split::Train => [30, 20, 20, 15, 15],
            Split::WikiSim => [45, 20, 15, 10, 10],
            Split::C4Sim => [20, 25, 20, 20, 15],
        }
    }

    fn stream(&self) -> u64 {
        match self {
            Split::Train => 11,
            Split::WikiSim => 22,
            Split::C4Sim => 33,
        }
    }
}

fn sentence(rng: &mut Pcg64, mix: &[u32; 5]) -> String {
    let total: u32 = mix.iter().sum();
    let mut pick = rng.below(total as usize) as u32;
    let mut kind = 0;
    for (i, &w) in mix.iter().enumerate() {
        if pick < w {
            kind = i;
            break;
        }
        pick -= w;
    }
    match kind {
        0 => {
            // Simple SVO with an adjective.
            let (a, _, _) = *rng.choose(ADJ_PAIRS);
            format!(
                "the {a} {} {} the {} . ",
                rng.choose(ANIMALS),
                rng.choose(VERBS),
                rng.choose(OBJECTS)
            )
        }
        1 => {
            // Coreference rule: "because it was fast" ⇒ the chaser;
            // "because it was slow" ⇒ the chased. Statement form names the
            // referent explicitly so the rule is learnable.
            let a1 = *rng.choose(ANIMALS);
            let mut a2 = *rng.choose(ANIMALS);
            while a2 == a1 {
                a2 = *rng.choose(ANIMALS);
            }
            let fast = rng.chance(0.5);
            let (adj, who) = if fast { ("fast", a1) } else { ("slow", a2) };
            format!("the {a1} chased the {a2} because it was {adj} . the {adj} one was the {who} . ")
        }
        2 => {
            // Entailment rule: "X is <base>" entails "X is <synonym>".
            let (base, syn, _ant) = *rng.choose(ADJ_PAIRS);
            let o = *rng.choose(OBJECTS);
            format!("the {o} is {base} . that means the {o} is {syn} . ")
        }
        3 => {
            // Affordance rule.
            let (tool, act, _bad) = *rng.choose(TOOL_ACTIONS);
            format!("you use a {tool} to {act} . ")
        }
        _ => {
            // Arithmetic (sums ≤ 9 so the answer is a single word).
            let x = rng.below(5);
            let y = rng.below(5);
            format!(
                "{} plus {} is {} . ",
                NUMBERS[x], NUMBERS[y], NUMBERS[x + y]
            )
        }
    }
}

/// Generate `n_tokens` bytes of the given split, deterministically.
pub fn generate(split: Split, n_tokens: usize, seed: u64) -> Vec<u8> {
    let mut rng = Pcg64::new(seed, split.stream());
    let mix = split.mix();
    let mut out = Vec::with_capacity(n_tokens + 80);
    while out.len() < n_tokens {
        out.extend_from_slice(sentence(&mut rng, &mix).as_bytes());
    }
    out.truncate(n_tokens);
    out
}

/// Pack a token stream into (batch, seq+1) next-token-prediction batches
/// with random window starts. Returns row-major i32 suitable for the
/// `train_*` artifacts.
pub fn sample_batch(
    tokens: &[u8],
    batch: usize,
    seq_plus1: usize,
    rng: &mut Pcg64,
) -> Vec<i32> {
    assert!(tokens.len() > seq_plus1, "corpus shorter than a window");
    let mut out = Vec::with_capacity(batch * seq_plus1);
    for _ in 0..batch {
        let start = rng.below(tokens.len() - seq_plus1);
        out.extend(
            tokens[start..start + seq_plus1]
                .iter()
                .map(|&b| b as i32),
        );
    }
    out
}

/// Sequential non-overlapping windows for perplexity (row-major i32,
/// `count` rows of `seq` tokens each, plus targets = next byte).
pub fn eval_windows(tokens: &[u8], seq: usize, count: usize) -> Vec<Vec<i32>> {
    let mut wins = Vec::new();
    let mut pos = 0;
    while wins.len() < count && pos + seq + 1 <= tokens.len() {
        wins.push(tokens[pos..pos + seq + 1].iter().map(|&b| b as i32).collect());
        pos += seq;
    }
    wins
}

// ---------------------------------------------------------------------------
// Zero-shot proxy tasks
// ---------------------------------------------------------------------------

/// The five proxy tasks mirroring the paper's benchmark columns.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    WinoSim,
    RteSim,
    PiqaSim,
    ArcESim,
    ArcCSim,
}

pub const ALL_TASKS: [Task; 5] = [
    Task::WinoSim,
    Task::RteSim,
    Task::PiqaSim,
    Task::ArcESim,
    Task::ArcCSim,
];

impl Task {
    pub fn name(&self) -> &'static str {
        match self {
            Task::WinoSim => "wino-sim",
            Task::RteSim => "rte-sim",
            Task::PiqaSim => "piqa-sim",
            Task::ArcESim => "arce-sim",
            Task::ArcCSim => "arcc-sim",
        }
    }
}

/// One two-choice item: score `prompt ++ choices[i]` by log-prob; the model
/// is correct iff argmax_i logp == correct.
#[derive(Clone, Debug)]
pub struct TaskItem {
    pub prompt: String,
    pub choices: [String; 2],
    pub correct: usize,
}

/// Generate `n` deterministic items of a task.
pub fn task_items(task: Task, n: usize, seed: u64) -> Vec<TaskItem> {
    let mut rng = Pcg64::new(seed, 100 + task as u64);
    (0..n)
        .map(|_| match task {
            Task::WinoSim => {
                let a1 = *rng.choose(ANIMALS);
                let mut a2 = *rng.choose(ANIMALS);
                while a2 == a1 {
                    a2 = *rng.choose(ANIMALS);
                }
                let fast = rng.chance(0.5);
                let adj = if fast { "fast" } else { "slow" };
                let correct = if fast { 0 } else { 1 };
                TaskItem {
                    prompt: format!(
                        "the {a1} chased the {a2} because it was {adj} . the {adj} one was the "
                    ),
                    choices: [format!("{a1} ."), format!("{a2} .")],
                    correct,
                }
            }
            Task::RteSim => {
                let (base, syn, ant) = *rng.choose(ADJ_PAIRS);
                let o = *rng.choose(OBJECTS);
                let swap = rng.chance(0.5);
                TaskItem {
                    prompt: format!("the {o} is {base} . that means the {o} is "),
                    choices: if swap {
                        [format!("{ant} ."), format!("{syn} .")]
                    } else {
                        [format!("{syn} ."), format!("{ant} .")]
                    },
                    correct: usize::from(swap),
                }
            }
            Task::PiqaSim => {
                let (tool, act, bad) = *rng.choose(TOOL_ACTIONS);
                let swap = rng.chance(0.5);
                TaskItem {
                    prompt: format!("you use a {tool} to "),
                    choices: if swap {
                        [format!("{bad} ."), format!("{act} .")]
                    } else {
                        [format!("{act} ."), format!("{bad} .")]
                    },
                    correct: usize::from(swap),
                }
            }
            Task::ArcESim => {
                let x = rng.below(5);
                let y = rng.below(5);
                let wrong = (x + y + 1 + rng.below(3)) % 10;
                let swap = rng.chance(0.5);
                TaskItem {
                    prompt: format!("{} plus {} is ", NUMBERS[x], NUMBERS[y]),
                    choices: if swap {
                        [format!("{} .", NUMBERS[wrong]), format!("{} .", NUMBERS[x + y])]
                    } else {
                        [format!("{} .", NUMBERS[x + y]), format!("{} .", NUMBERS[wrong])]
                    },
                    correct: usize::from(swap),
                }
            }
            Task::ArcCSim => {
                // Harder: unseen-at-train compositional form (two steps).
                let x = 1 + rng.below(4);
                let y = 1 + rng.below(4);
                let sum = x + y;
                let wrong = if rng.chance(0.5) && sum >= 2 { sum - 1 } else { sum + 1 };
                let swap = rng.chance(0.5);
                TaskItem {
                    prompt: format!(
                        "{} plus {} plus zero is ",
                        NUMBERS[x], NUMBERS[y]
                    ),
                    choices: if swap {
                        [format!("{} .", NUMBERS[wrong]), format!("{} .", NUMBERS[sum])]
                    } else {
                        [format!("{} .", NUMBERS[sum]), format!("{} .", NUMBERS[wrong])]
                    },
                    correct: usize::from(swap),
                }
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let a = generate(Split::WikiSim, 4096, 42);
        let b = generate(Split::WikiSim, 4096, 42);
        assert_eq!(a, b);
        let c = generate(Split::WikiSim, 4096, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn splits_differ() {
        let a = generate(Split::WikiSim, 2048, 1);
        let b = generate(Split::C4Sim, 2048, 1);
        assert_ne!(a, b);
    }

    #[test]
    fn corpus_is_ascii_lowercase() {
        let data = generate(Split::Train, 8192, 7);
        assert!(data
            .iter()
            .all(|&b| b == b' ' || b == b'.' || b.is_ascii_lowercase()));
    }

    #[test]
    fn coreference_rule_holds_in_corpus() {
        // Every "the fast one was the X" mention agrees with the chaser.
        let text = String::from_utf8(generate(Split::Train, 200_000, 3)).unwrap();
        let mut checked = 0;
        for seg in text.split(" . ") {
            if let Some(rest) = seg.strip_prefix("the ") {
                if rest.contains(" chased the ") && seg.len() < 200 {
                    // parse "X chased the Y because it was ADJ . the ADJ one was the W"
                    continue;
                }
            }
            if let Some(idx) = seg.find(" one was the ") {
                let who = &seg[idx + " one was the ".len()..];
                assert!(ANIMALS.contains(&who.trim()), "bad referent {who}");
                checked += 1;
            }
        }
        assert!(checked > 50, "rule sentences too rare: {checked}");
    }

    #[test]
    fn batches_have_right_shape_and_range() {
        let data = generate(Split::Train, 10_000, 5);
        let mut rng = Pcg64::new(9, 9);
        let b = sample_batch(&data, 8, 65, &mut rng);
        assert_eq!(b.len(), 8 * 65);
        assert!(b.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn eval_windows_non_overlapping() {
        let data = generate(Split::WikiSim, 10_000, 5);
        let wins = eval_windows(&data, 64, 20);
        assert_eq!(wins.len(), 20);
        for w in &wins {
            assert_eq!(w.len(), 65);
        }
        // Window i's tokens continue window i-1 (stride = seq).
        assert_eq!(wins[0][64], wins[1][0]);
    }

    #[test]
    fn task_items_have_valid_rules() {
        for task in ALL_TASKS {
            let items = task_items(task, 64, 11);
            assert_eq!(items.len(), 64);
            for it in &items {
                assert!(it.correct < 2);
                assert_ne!(it.choices[0], it.choices[1]);
                assert!(!it.prompt.is_empty());
            }
            // Both answer positions occur (no positional shortcut).
            let firsts = items.iter().filter(|i| i.correct == 0).count();
            assert!(firsts > 8 && firsts < 56, "{task:?} positional bias");
        }
    }

    #[test]
    fn wino_items_agree_with_rule() {
        for it in task_items(Task::WinoSim, 32, 3) {
            let fast = it.prompt.contains("was fast");
            // fast ⇒ chaser (first animal in prompt) is the answer.
            let chaser = it.prompt[4..].split(' ').next().unwrap().to_string();
            let answer = it.choices[it.correct].split(' ').next().unwrap();
            if fast {
                assert_eq!(answer, chaser);
            } else {
                assert_ne!(answer, chaser);
            }
        }
    }

    #[test]
    fn arithmetic_items_sum_correctly() {
        for it in task_items(Task::ArcESim, 32, 4) {
            let words: Vec<&str> = it.prompt.split(' ').collect();
            let x = NUMBERS.iter().position(|&n| n == words[0]).unwrap();
            let y = NUMBERS.iter().position(|&n| n == words[2]).unwrap();
            let ans = it.choices[it.correct].split(' ').next().unwrap();
            assert_eq!(ans, NUMBERS[x + y]);
        }
    }
}
