//! Micro-benchmarks of the Rust compute substrate (the L3 hot paths the
//! profiler pointed at: matmul, SVD, LDLQ, E8 rounding, FWHT, LPLR), the
//! fused packed `(Q+LR)·x` serving kernels vs the historical
//! reconstruct-then-matmul path, and the `decode` group — the word-level
//! specialized unpackers vs the scalar `BitReader` reference, plus the
//! fused dequant-dot decode-step kernel vs the blocked panel kernel.
//!
//! Usage: `cargo bench --bench bench_kernels -- [--fast] [group-filter]...`
//! (`--fast` is the CI budget; e.g. `-- --fast decode` runs only the
//! decode group). Output: human-readable lines for EXPERIMENTS.md §Perf
//! plus machine-readable `BENCH_kernels.json` (uploaded by CI).

use odlri::benchkit::{group, BenchArgs, JsonReport};
use odlri::fused::FusedQlrMatrix;
use odlri::hessian::Hessian;
use odlri::linalg::{svd_jacobi, truncated_svd};
use odlri::lowrank::{lplr, whitened_svd_lr, LowRankConfig, LrPair};
use odlri::quant::{make_quantizer, E8Lattice, PackedMatrix, Quantizer, UniformQuantizer};
use odlri::tensor::{matmul, set_matmul_threads, Matrix};
use odlri::util::rng::Pcg64;

fn main() {
    let args = BenchArgs::from_env();
    let mut json = JsonReport::new("kernels");
    let mut rng = Pcg64::new(1, 1);

    if args.want("matmul") {
        group("matmul");
        for &(m, k, n) in &[(128usize, 128usize, 128usize), (352, 128, 512), (512, 512, 512)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let flops = 2.0 * (m * k * n) as f64;
            set_matmul_threads(1);
            let s = args.bencher(&format!("matmul_{m}x{k}x{n}_1t")).run(|| matmul(&a, &b));
            println!("{}", s.line_throughput(flops, "flop"));
            json.record_with(&s, Some((flops, "flop")));
            set_matmul_threads(0);
            let s = args.bencher(&format!("matmul_{m}x{k}x{n}_mt")).run(|| matmul(&a, &b));
            println!("{}", s.line_throughput(flops, "flop"));
            json.record_with(&s, Some((flops, "flop")));
        }
    }

    if args.want("svd") {
        group("svd");
        for &(m, n, r) in &[(128usize, 128usize, 16usize), (352, 128, 16), (512, 512, 32)] {
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            if m.min(n) <= 128 {
                let s = args.bencher(&format!("svd_jacobi_{m}x{n}")).run(|| svd_jacobi(&a));
                println!("{}", s.line());
                json.record(&s);
            }
            let mut r1 = Pcg64::new(2, 2);
            let b = args.bencher(&format!("truncated_svd_{m}x{n}_r{r}"));
            let s = b.run(|| truncated_svd(&a, r, &mut r1));
            println!("{}", s.line());
            json.record(&s);
        }
    }

    let w = Matrix::randn(352, 128, 1.0, &mut rng);
    let e8 = E8Lattice::new(2);
    let uq = UniformQuantizer::new(2, usize::MAX);
    if args.want("quantizers") {
        group("quantizers");
        let s = args.bencher("e8_quantize_352x128").run(|| e8.quantize(&w));
        println!("{}", s.line_throughput((352 * 128) as f64, "weights"));
        json.record_with(&s, Some(((352 * 128) as f64, "weights")));
        let s = args.bencher("uniform2_quantize_352x128").run(|| uq.quantize(&w));
        println!("{}", s.line_throughput((352 * 128) as f64, "weights"));
        json.record_with(&s, Some(((352 * 128) as f64, "weights")));
    }

    let x = Matrix::randn(128, 512, 1.0, &mut rng);
    if args.want("ldlq") {
        group("ldlq");
        let h = Hessian::from_acts(&x).regularized(1e-4);
        let s = args.bencher("ldlq_e8_352x128").run(|| e8.quantize_with_hessian(&w, &h));
        println!("{}", s.line());
        json.record(&s);
        let s = args.bencher("ldlq_uniform_352x128").run(|| uq.quantize_with_hessian(&w, &h));
        println!("{}", s.line());
        json.record(&s);
    }

    if args.want("fwht") {
        group("fwht");
        let mut wt = Matrix::randn(352, 128, 1.0, &mut rng);
        let s = args.bencher("fwht_rows_352x128").run(|| {
            odlri::hadamard::fwht_rows(&mut wt);
        });
        println!("{}", s.line_throughput((352 * 128) as f64, "elem"));
        json.record_with(&s, Some(((352 * 128) as f64, "elem")));
    }

    let lr_cfg = LowRankConfig {
        rank: 16,
        lr_bits: 4,
        lplr_iters: 10,
        reg: 1e-4,
    };
    if args.want("lowrank") {
        group("lowrank");
        let h = Hessian::from_acts(&x).regularized(1e-4);
        let mut r2 = Pcg64::new(3, 3);
        let b = args.bencher("whitened_svd_352x128_r16");
        let s = b.run(|| whitened_svd_lr(&w, &h, 16, &mut r2));
        println!("{}", s.line());
        json.record(&s);
        let mut r3 = Pcg64::new(4, 4);
        let init = whitened_svd_lr(&w, &h, 16, &mut r3);
        let b = args.bencher("lplr10_352x128_r16");
        let s = b.run(|| lplr(&w, &h, init.clone(), &lr_cfg));
        println!("{}", s.line());
        json.record(&s);
    }

    if args.want("joint") {
        group("joint-iteration (1 outer iter, 352x128)");
        let hess = Hessian::from_acts(&x);
        let quant = E8Lattice::new(2);
        let jc = odlri::decompose::JointConfig {
            outer_iters: 1,
            lowrank: lr_cfg,
            ..Default::default()
        };
        let opt = odlri::decompose::JointOptimizer::new(&quant, jc);
        let s = args.bencher("joint_1iter_odlri").run(|| {
            opt.run(&w, &hess, &odlri::decompose::Initializer::Odlri { k: 4 })
        });
        println!("{}", s.line());
        json.record(&s);
    }

    // Serving-shaped problem shared by the fused groups: a 512×256
    // projection, rank-16 correction.
    let (m, n, rank) = (512usize, 256usize, 16usize);
    let wq = Matrix::randn(m, n, 1.0, &mut rng);
    let lr = LrPair {
        l: Matrix::randn(m, rank, 0.05, &mut rng),
        r: Matrix::randn(rank, n, 0.05, &mut rng),
    };

    if args.want("fused") {
        group("fused (Q+LR)·x vs reconstruct-then-matmul");
        // The fused kernel dequantizes Q on the fly and applies L·R as two
        // skinny matmuls; the reconstruct path (what the eval stack used to
        // do per matrix) densifies Q + L·R first.
        for &bits in &[2u32, 4] {
            let packed = PackedMatrix::pack(&wq, bits, 64);
            let fm = FusedQlrMatrix::new(packed, lr.clone()).expect("fused build");
            for &batch in &[1usize, 8, 32, 96] {
                let x = Matrix::randn(n, batch, 1.0, &mut rng);
                let flops = 2.0 * (m * n * batch) as f64;
                let b = args.bencher(&format!("reconstruct_{m}x{n}_q{bits}b_x{batch}"));
                let s = b.run(|| {
                    let dense = fm.q.unpack().add(&fm.l.dot(&fm.r));
                    dense.dot(&x)
                });
                println!("{}", s.line_throughput(flops, "flop"));
                json.record_with(&s, Some((flops, "flop")));
                let b = args.bencher(&format!("fused_{m}x{n}_q{bits}b_x{batch}"));
                let s = b.run(|| fm.matmul(&x));
                println!("{}", s.line_throughput(flops, "flop"));
                json.record_with(&s, Some((flops, "flop")));
            }
        }

        group("fused (Q+LR)·x scheme-native decode (e8 / mxint / rotated)");
        // The v2 container serves every quantizer's own codes; these cases
        // track the decode cost of the non-uniform layouts and of folding
        // the Hadamard rotation into the activations.
        let mut variants: Vec<(String, FusedQlrMatrix)> = Vec::new();
        for scheme in ["e8", "mxint"] {
            let quant = make_quantizer(scheme, 2, 64).expect("quantizer");
            let qout = quant.quantize(&wq);
            let fm = FusedQlrMatrix::new(qout.packed, lr.clone()).expect("fused build");
            variants.push((scheme.to_string(), fm));
        }
        {
            let inc = odlri::hadamard::Incoherence::new(m, n, &mut rng);
            let qout = UniformQuantizer::new(2, 64).quantize(&inc.apply(&wq));
            let packed = qout
                .packed
                .with_rotation(inc.left_signs.clone(), inc.right_signs.clone());
            let fm = FusedQlrMatrix::new(packed, lr.clone()).expect("fused build");
            variants.push(("uniform_rot".to_string(), fm));
        }
        for (name, fm) in &variants {
            for &batch in &[8usize, 96] {
                let x = Matrix::randn(n, batch, 1.0, &mut rng);
                let flops = 2.0 * (m * n * batch) as f64;
                let b = args.bencher(&format!("fused_{m}x{n}_{name}_x{batch}"));
                let s = b.run(|| fm.matmul(&x));
                println!("{}", s.line_throughput(flops, "flop"));
                json.record_with(&s, Some((flops, "flop")));
            }
        }
    }

    if args.want("decode") {
        group("decode: specialized word-level unpackers vs scalar BitReader reference (1 thread)");
        // Full-matrix row decode per scheme × stored bit-width. Both sides
        // produce bit-identical f32 rows (property-tested); the benchmark
        // is rows/s and packed GB/s over the serialized Q payload.
        let (dm, dn) = (512usize, 1024usize);
        let wd = Matrix::randn(dm, dn, 1.0, &mut rng);
        let mut cases: Vec<(String, PackedMatrix)> = Vec::new();
        for &bits in &[2u32, 3, 4, 8] {
            cases.push((format!("uniform{bits}b"), PackedMatrix::pack(&wd, bits, 64)));
        }
        for &bits in &[2u32, 4] {
            // E8 stores bits+2 wide codes: 4- and 6-bit stored widths.
            let quant = make_quantizer("e8", bits, 64).expect("quantizer");
            cases.push((format!("e8_{bits}b"), quant.quantize(&wd).packed));
        }
        let quant = make_quantizer("mxint", 4, 32).expect("quantizer");
        cases.push(("mxint4b".to_string(), quant.quantize(&wd).packed));
        let mut row = vec![0f32; dn];
        let mut codes: Vec<i32> = Vec::new();
        for (name, p) in &cases {
            let bytes = p.byte_size() as f64;
            for kind in ["ref", "fast"] {
                let specialized = kind == "fast";
                let s = args.bencher(&format!("decode_{kind}_{name}_{dm}x{dn}")).run(|| {
                    let mut acc = 0f32;
                    for i in 0..dm {
                        if specialized {
                            p.dequant_row_fast_into(i, &mut codes, &mut row);
                        } else {
                            p.dequant_row_into(i, &mut row);
                        }
                        acc += row[0] + row[dn - 1];
                    }
                    acc
                });
                println!(
                    "{}  [{:.2} GB/s packed]",
                    s.line_throughput(dm as f64, "rows"),
                    bytes / s.median_s / 1e9
                );
                json.record_with(&s, Some((dm as f64, "rows")));
            }
        }

        group("decode-step kernel: fused dequant-dot vs panel (t activation rows)");
        // The per-token generation hot path: decode_matmul_t (group-hoisted
        // fused dequant-dot, no panel) vs matmul_t (decode panel +
        // matmul_nt) at decode-regime row counts.
        for &bits in &[2u32, 4] {
            let packed = PackedMatrix::pack(&wq, bits, 64);
            let fm = FusedQlrMatrix::new(packed, lr.clone()).expect("fused build");
            for &t in &[1usize, 4] {
                let x = Matrix::randn(t, n, 1.0, &mut rng);
                let flops = 2.0 * (m * n * t) as f64;
                let b = args.bencher(&format!("decode_step_panel_q{bits}b_t{t}"));
                let s = b.run(|| fm.matmul_t(&x));
                println!("{}", s.line_throughput(flops, "flop"));
                json.record_with(&s, Some((flops, "flop")));
                let b = args.bencher(&format!("decode_step_fused_q{bits}b_t{t}"));
                let s = b.run(|| fm.decode_matmul_t(&x));
                println!("{}", s.line_throughput(flops, "flop"));
                json.record_with(&s, Some((flops, "flop")));
            }
        }
    }

    if !json.is_empty() {
        let path = json.write(std::path::Path::new(".")).expect("write BENCH_kernels.json");
        println!("\nwrote {}", path.display());
    }
}
