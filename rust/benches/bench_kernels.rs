//! Micro-benchmarks of the Rust compute substrate (the L3 hot paths the
//! profiler pointed at: matmul, SVD, LDLQ, E8 rounding, FWHT, LPLR) plus
//! the fused packed `(Q+LR)·x` serving kernels vs the historical
//! reconstruct-then-matmul path. Output format feeds EXPERIMENTS.md §Perf.

use odlri::benchkit::{group, Bencher};
use odlri::fused::FusedQlrMatrix;
use odlri::hessian::Hessian;
use odlri::linalg::{svd_jacobi, truncated_svd};
use odlri::lowrank::{lplr, whitened_svd_lr, LowRankConfig, LrPair};
use odlri::quant::{E8Lattice, PackedMatrix, Quantizer, UniformQuantizer};
use odlri::tensor::{matmul, set_matmul_threads, Matrix};
use odlri::util::rng::Pcg64;

fn main() {
    let mut rng = Pcg64::new(1, 1);

    group("matmul");
    for &(m, k, n) in &[(128usize, 128usize, 128usize), (352, 128, 512), (512, 512, 512)] {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        set_matmul_threads(1);
        let s = Bencher::new(&format!("matmul_{m}x{k}x{n}_1t")).fast().run(|| matmul(&a, &b));
        println!("{}", s.line_throughput(2.0 * (m * k * n) as f64, "flop"));
        set_matmul_threads(0);
        let s = Bencher::new(&format!("matmul_{m}x{k}x{n}_mt")).fast().run(|| matmul(&a, &b));
        println!("{}", s.line_throughput(2.0 * (m * k * n) as f64, "flop"));
    }

    group("svd");
    for &(m, n, r) in &[(128usize, 128usize, 16usize), (352, 128, 16), (512, 512, 32)] {
        let a = Matrix::randn(m, n, 1.0, &mut rng);
        if m.min(n) <= 128 {
            let s = Bencher::new(&format!("svd_jacobi_{m}x{n}")).fast().run(|| svd_jacobi(&a));
            println!("{}", s.line());
        }
        let mut r1 = Pcg64::new(2, 2);
        let s = Bencher::new(&format!("truncated_svd_{m}x{n}_r{r}"))
            .fast()
            .run(|| truncated_svd(&a, r, &mut r1));
        println!("{}", s.line());
    }

    group("quantizers");
    let w = Matrix::randn(352, 128, 1.0, &mut rng);
    let e8 = E8Lattice::new(2);
    let s = Bencher::new("e8_quantize_352x128").fast().run(|| e8.quantize(&w));
    println!("{}", s.line_throughput((352 * 128) as f64, "weights"));
    let uq = UniformQuantizer::new(2, usize::MAX);
    let s = Bencher::new("uniform2_quantize_352x128").fast().run(|| uq.quantize(&w));
    println!("{}", s.line_throughput((352 * 128) as f64, "weights"));

    group("ldlq");
    let x = Matrix::randn(128, 512, 1.0, &mut rng);
    let h = Hessian::from_acts(&x).regularized(1e-4);
    let s = Bencher::new("ldlq_e8_352x128").fast().run(|| e8.quantize_with_hessian(&w, &h));
    println!("{}", s.line());
    let s = Bencher::new("ldlq_uniform_352x128").fast().run(|| uq.quantize_with_hessian(&w, &h));
    println!("{}", s.line());

    group("fwht");
    let mut wt = Matrix::randn(352, 128, 1.0, &mut rng);
    let s = Bencher::new("fwht_rows_352x128").fast().run(|| {
        odlri::hadamard::fwht_rows(&mut wt);
    });
    println!("{}", s.line_throughput((352 * 128) as f64, "elem"));

    group("lowrank");
    let mut r2 = Pcg64::new(3, 3);
    let s = Bencher::new("whitened_svd_352x128_r16")
        .fast()
        .run(|| whitened_svd_lr(&w, &h, 16, &mut r2));
    println!("{}", s.line());
    let cfg = LowRankConfig {
        rank: 16,
        lr_bits: 4,
        lplr_iters: 10,
        reg: 1e-4,
    };
    let mut r3 = Pcg64::new(4, 4);
    let init = whitened_svd_lr(&w, &h, 16, &mut r3);
    let s = Bencher::new("lplr10_352x128_r16")
        .fast()
        .run(|| lplr(&w, &h, init.clone(), &cfg));
    println!("{}", s.line());

    group("joint-iteration (1 outer iter, 352x128)");
    let hess = Hessian::from_acts(&x);
    let quant = E8Lattice::new(2);
    let jc = odlri::decompose::JointConfig {
        outer_iters: 1,
        lowrank: cfg,
        ..Default::default()
    };
    let opt = odlri::decompose::JointOptimizer::new(&quant, jc);
    let s = Bencher::new("joint_1iter_odlri").fast().run(|| {
        opt.run(&w, &hess, &odlri::decompose::Initializer::Odlri { k: 4 })
    });
    println!("{}", s.line());

    group("fused (Q+LR)·x vs reconstruct-then-matmul");
    // Serving-shaped problem: a 512×256 projection, rank-16 correction,
    // X = (in_dim, batch) activations. The fused kernel dequantizes Q on
    // the fly and applies L·R as two skinny matmuls; the reconstruct path
    // (what the eval stack used to do per matrix) densifies Q + L·R first.
    let (m, n, rank) = (512usize, 256usize, 16usize);
    let wq = Matrix::randn(m, n, 1.0, &mut rng);
    let lr = LrPair {
        l: Matrix::randn(m, rank, 0.05, &mut rng),
        r: Matrix::randn(rank, n, 0.05, &mut rng),
    };
    for &bits in &[2u32, 4] {
        let packed = PackedMatrix::pack(&wq, bits, 64);
        let fm = FusedQlrMatrix::new(packed, lr.clone()).expect("fused build");
        for &batch in &[1usize, 8, 32, 96] {
            let x = Matrix::randn(n, batch, 1.0, &mut rng);
            let flops = 2.0 * (m * n * batch) as f64;
            let s = Bencher::new(&format!("reconstruct_{m}x{n}_q{bits}b_x{batch}"))
                .fast()
                .run(|| {
                    let dense = fm.q.unpack().add(&fm.l.dot(&fm.r));
                    dense.dot(&x)
                });
            println!("{}", s.line_throughput(flops, "flop"));
            let s = Bencher::new(&format!("fused_{m}x{n}_q{bits}b_x{batch}"))
                .fast()
                .run(|| fm.matmul(&x));
            println!("{}", s.line_throughput(flops, "flop"));
        }
    }

    group("fused (Q+LR)·x scheme-native decode (e8 / mxint / rotated)");
    // The v2 container serves every quantizer's own codes; these cases
    // track the decode cost of the non-uniform layouts and of folding the
    // Hadamard rotation into the activations.
    let mut variants: Vec<(String, FusedQlrMatrix)> = Vec::new();
    for scheme in ["e8", "mxint"] {
        let quant = odlri::quant::make_quantizer(scheme, 2, 64).expect("quantizer");
        let qout = quant.quantize(&wq);
        let fm = FusedQlrMatrix::new(qout.packed, lr.clone()).expect("fused build");
        variants.push((scheme.to_string(), fm));
    }
    {
        let inc = odlri::hadamard::Incoherence::new(m, n, &mut rng);
        let qout = UniformQuantizer::new(2, 64).quantize(&inc.apply(&wq));
        let packed = qout
            .packed
            .with_rotation(inc.left_signs.clone(), inc.right_signs.clone());
        let fm = FusedQlrMatrix::new(packed, lr.clone()).expect("fused build");
        variants.push(("uniform_rot".to_string(), fm));
    }
    for (name, fm) in &variants {
        for &batch in &[8usize, 96] {
            let x = Matrix::randn(n, batch, 1.0, &mut rng);
            let flops = 2.0 * (m * n * batch) as f64;
            let s = Bencher::new(&format!("fused_{m}x{n}_{name}_x{batch}"))
                .fast()
                .run(|| fm.matmul(&x));
            println!("{}", s.line_throughput(flops, "flop"));
        }
    }
}
