//! One bench per paper table/figure: times the *workload that regenerates
//! it* (reduced sweeps — the full regeneration is `odlri exp <id>`).
//!
//! table1/fig2/fig3/fig4/fig5/table8 → matrix-level joint optimization;
//! table2/3/4/5/9/10/11 → one pipeline cell (compress 7 matrices) each.

use std::collections::BTreeMap;

use odlri::benchkit::{group, Bencher};
use odlri::calib::{synthetic_calib, synthetic_weight};
use odlri::coordinator::{CompressionPipeline, InitKind, PipelineConfig};
use odlri::decompose::{Initializer, JointConfig, JointOptimizer};
use odlri::hessian::Hessian;
use odlri::lowrank::LowRankConfig;
use odlri::model::ModelParams;
use odlri::quant::E8Lattice;
use odlri::runtime::FamilySpec;
use odlri::tensor::Matrix;
use odlri::util::fnv1a;

fn matrix_problem(proj: &str, seed: u64) -> (Matrix, Hessian) {
    let (m, n) = match proj {
        "wgate" | "wup" => (352, 128),
        "wdown" => (128, 352),
        _ => (128, 128),
    };
    let c = synthetic_calib(n, 4 * n, 4, 20.0, seed);
    let w = synthetic_weight(m, n, &c.outlier_channels, seed);
    (w, c.hessian)
}

fn run_joint(w: &Matrix, h: &Hessian, init: &Initializer, iters: usize, lr_bits: u32) {
    let quant = E8Lattice::new(2);
    let cfg = JointConfig {
        outer_iters: iters,
        lowrank: LowRankConfig {
            rank: 8,
            lr_bits,
            lplr_iters: 3,
            reg: 1e-4,
        },
        ..Default::default()
    };
    JointOptimizer::new(&quant, cfg).run(w, h, init);
}

/// A one-layer toy model for pipeline cells (artifact-free).
fn toy_pipeline_inputs() -> (ModelParams, BTreeMap<String, Hessian>) {
    let fam = FamilySpec {
        name: "bench".into(),
        params: vec![
            ("embed".into(), vec![32, 128]),
            ("layer0.ln1".into(), vec![128]),
            ("layer0.wq".into(), vec![128, 128]),
            ("layer0.wk".into(), vec![128, 128]),
            ("layer0.wv".into(), vec![128, 128]),
            ("layer0.wo".into(), vec![128, 128]),
            ("layer0.ln2".into(), vec![128]),
            ("layer0.wgate".into(), vec![352, 128]),
            ("layer0.wup".into(), vec![352, 128]),
            ("layer0.wdown".into(), vec![128, 352]),
            ("ln_f".into(), vec![128]),
            ("unembed".into(), vec![32, 128]),
        ],
        projections: vec![
            "layer0.wq".into(),
            "layer0.wk".into(),
            "layer0.wv".into(),
            "layer0.wo".into(),
            "layer0.wgate".into(),
            "layer0.wup".into(),
            "layer0.wdown".into(),
        ],
        vocab: 32,
        d_model: 128,
        n_layers: 1,
        d_ff: 352,
        n_heads: 4,
        n_kv_heads: 4,
        mlp: "swiglu".into(),
        rope_theta: 10000.0,
    };
    let mut params = ModelParams::init(&fam, 1);
    let mut hessians = BTreeMap::new();
    for name in fam.projections.clone() {
        let shape = fam.param_shape(&name).unwrap().to_vec();
        let c = synthetic_calib(shape[1], 3 * shape[1], 3, 20.0, fnv1a(name.as_bytes()));
        params
            .set_matrix(
                &name,
                &synthetic_weight(shape[0], shape[1], &c.outlier_channels, 2),
            )
            .unwrap();
        hessians.insert(name, c.hessian);
    }
    (params, hessians)
}

fn pipeline_cell(init: InitKind, rank: usize, lr_bits: u32, scheme: &str, bits: u32) {
    let (params, hessians) = toy_pipeline_inputs();
    let cfg = PipelineConfig {
        init,
        rank,
        lr_bits,
        q_scheme: scheme.into(),
        q_bits: bits,
        q_group: 32,
        outer_iters: 3,
        lplr_iters: 3,
        workers: 4,
        ..Default::default()
    };
    CompressionPipeline::new(cfg).run(&params, &hessians).unwrap();
}

fn main() {
    group("table1 / tables12-13 — init-role traces (key proj, 5 iters)");
    let (w, h) = matrix_problem("wk", 11);
    for (name, init) in [
        ("table1_zero", Initializer::Zero),
        ("table1_lrapprox", Initializer::LrApproxW),
    ] {
        let s = Bencher::new(name).iters(3, 10).run(|| run_joint(&w, &h, &init, 5, 16));
        println!("{}", s.line());
    }

    group("fig2/fig3 — per-iteration scale+error trace (3 inits, 4-bit LR)");
    for (name, init) in [
        ("fig23_zero", Initializer::Zero),
        ("fig23_lrapprox", Initializer::LrApproxW),
        ("fig23_odlri", Initializer::Odlri { k: 4 }),
    ] {
        let s = Bencher::new(name).iters(3, 10).run(|| run_joint(&w, &h, &init, 5, 4));
        println!("{}", s.line());
    }

    group("fig4/fig5 — wider projection sweep (down proj)");
    let (wd, hd) = matrix_problem("wdown", 12);
    let s = Bencher::new("fig45_down_odlri")
        .iters(3, 10)
        .run(|| run_joint(&wd, &hd, &Initializer::Odlri { k: 4 }, 5, 4));
    println!("{}", s.line());

    group("table8 — ODLRI init with H vs H_o");
    let mut rng = odlri::util::rng::Pcg64::new(5, 5);
    let s = Bencher::new("table8_odlri_init").fast().run(|| {
        odlri::decompose::odlri_init(&w, &h, 8, 4, &mut rng)
    });
    println!("{}", s.line());
    let mut rng2 = odlri::util::rng::Pcg64::new(6, 6);
    let hr = h.regularized(1e-4);
    let s = Bencher::new("table8_full_h_init").fast().run(|| {
        odlri::lowrank::whitened_svd_lr(&w, &hr, 8, &mut rng2)
    });
    println!("{}", s.line());

    group("table2 — pipeline cell (2-bit E8 + 4-bit LR)");
    let s = Bencher::new("table2_cell_caldera").iters(2, 5).run(|| {
        pipeline_cell(InitKind::Caldera, 8, 4, "e8", 2)
    });
    println!("{}", s.line());
    let s = Bencher::new("table2_cell_odlri").iters(2, 5).run(|| {
        pipeline_cell(InitKind::Odlri, 8, 4, "e8", 2)
    });
    println!("{}", s.line());

    group("table3 — pipeline cell (16-bit LR)");
    let s = Bencher::new("table3_cell_odlri").iters(2, 5).run(|| {
        pipeline_cell(InitKind::Odlri, 8, 16, "e8", 2)
    });
    println!("{}", s.line());

    group("table4 — generalization cell (GQA-like shapes are identical here)");
    let s = Bencher::new("table4_cell_odlri").iters(2, 5).run(|| {
        pipeline_cell(InitKind::Odlri, 16, 4, "e8", 2)
    });
    println!("{}", s.line());

    group("table5 — k = r vs k < r");
    let s = Bencher::new("table5_k_eq_r").iters(2, 5).run(|| {
        pipeline_cell(InitKind::OdlriK(8), 8, 16, "e8", 2)
    });
    println!("{}", s.line());
    let s = Bencher::new("table5_k_lt_r").iters(2, 5).run(|| {
        pipeline_cell(InitKind::OdlriK(2), 8, 16, "e8", 2)
    });
    println!("{}", s.line());

    group("table9 — QuIP#-only (rank 0) vs +ODLRI");
    let s = Bencher::new("table9_rank0").iters(2, 5).run(|| {
        pipeline_cell(InitKind::Caldera, 0, 16, "e8", 2)
    });
    println!("{}", s.line());

    group("table10 — extreme rank 2");
    let s = Bencher::new("table10_rank2").iters(2, 5).run(|| {
        pipeline_cell(InitKind::Odlri, 2, 4, "e8", 2)
    });
    println!("{}", s.line());

    group("table11 — MXINT 3-bit cell");
    let s = Bencher::new("table11_mxint").iters(2, 5).run(|| {
        pipeline_cell(InitKind::Odlri, 4, 16, "mxint", 3)
    });
    println!("{}", s.line());
}
