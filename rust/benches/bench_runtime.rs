//! Runtime benches: artifact dispatch latency, dense vs fused-kernel
//! forward, packed-engine forward, train-step throughput. Runs on the XLA
//! backend when artifacts are present (and the `xla` feature is on),
//! otherwise on the native engine — no setup required.

use odlri::benchkit::{group, Bencher};
use odlri::corpus;
use odlri::fused::FusedModel;
use odlri::model::ModelParams;
use odlri::runtime::{Runtime, Value};
use odlri::tensor::Matrix;
use odlri::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let dir = odlri::runtime::default_artifact_dir();
    let rt = Runtime::open(&dir)?;
    println!(
        "engine: {}",
        if rt.is_native() { "native" } else { "xla/pjrt" }
    );
    let fam = rt.manifest.family("tl-7s")?.clone();
    let (b, s) = (rt.manifest.batch, rt.manifest.seq);
    let mut rng = Pcg64::new(1, 1);

    group("kernel dispatch");
    rt.warm("kernel_fused_qlr")?;
    let q = Matrix::randn(128, 128, 1.0, &mut rng);
    let l = Matrix::randn(128, 32, 1.0, &mut rng);
    let r = Matrix::randn(32, 128, 1.0, &mut rng);
    let x = Matrix::randn(128, 16, 1.0, &mut rng);
    let stats = Bencher::new("kernel_fused_qlr_128").fast().run(|| {
        rt.exec(
            "kernel_fused_qlr",
            &[
                Value::from_matrix(&q),
                Value::from_matrix(&l),
                Value::from_matrix(&r),
                Value::from_matrix(&x),
            ],
        )
        .unwrap()
    });
    println!("{}", stats.line());
    // Direct call without the Value boundary (dispatch overhead view).
    let stats = Bencher::new("rust_fused_equivalent")
        .fast()
        .run(|| odlri::fused::qlr_matmul(&q, &l, &r, &x));
    println!("{}", stats.line());

    group("model forward (B=8, S=96)");
    let params = ModelParams::init(&fam, 2);
    let data = corpus::generate(corpus::Split::WikiSim, 100_000, 1);
    rt.warm("fwd_tl-7s")?;
    let toks = corpus::sample_batch(&data, b, s, &mut rng);
    let stats = Bencher::new("fwd_tl-7s").iters(3, 20).run(|| {
        let mut inputs = params.values.clone();
        inputs.push(Value::from_vec_i32(vec![b, s], toks.clone()));
        rt.exec("fwd_tl-7s", &inputs).unwrap()
    });
    println!("{}", stats.line_throughput((b * s) as f64, "tok"));

    group("fused deploy forward (every projection via the fused kernel)");
    rt.warm("fwd_fused_tl-7s")?;
    let rank = rt.manifest.fused_rank;
    let mut fused_inputs = params.values.clone();
    for name in &fam.projections {
        let w = params.get_matrix(name)?;
        fused_inputs.push(Value::from_matrix(&w));
        fused_inputs.push(Value::from_matrix(&Matrix::zeros(w.rows(), rank)));
        fused_inputs.push(Value::from_matrix(&Matrix::zeros(rank, w.cols())));
    }
    fused_inputs.push(Value::from_vec_i32(vec![b, s], toks.clone()));
    let stats = Bencher::new("fwd_fused_tl-7s").iters(3, 20).run(|| {
        rt.exec("fwd_fused_tl-7s", &fused_inputs).unwrap()
    });
    println!("{}", stats.line_throughput((b * s) as f64, "tok"));

    group("packed fused engine (bit-packed Q, dequant on the fly)");
    for bits in [2u32, 8] {
        let fm = FusedModel::pack_dense(&params, "uniform", bits, 64)?;
        let stats = Bencher::new(&format!("fused_model_q{bits}b"))
            .iters(3, 20)
            .run(|| fm.forward(&toks, b, s).unwrap());
        println!(
            "{}  [{:.2} bits/weight]",
            stats.line_throughput((b * s) as f64, "tok"),
            fm.avg_bits()
        );
    }

    group("train step (B=8, S=97)");
    rt.warm("train_tl-7s")?;
    let n = params.values.len();
    let zeros: Vec<Value> = params
        .values
        .iter()
        .map(|v| {
            Value::from_vec_f32(
                v.shape().to_vec(),
                vec![0.0; v.shape().iter().product()],
            )
        })
        .collect();
    let ttoks = corpus::sample_batch(&data, b, s + 1, &mut rng);
    let stats = Bencher::new("train_step_tl-7s").iters(3, 10).run(|| {
        let mut inputs = Vec::with_capacity(3 * n + 2);
        inputs.extend(params.values.iter().cloned());
        inputs.extend(zeros.iter().cloned());
        inputs.extend(zeros.iter().cloned());
        inputs.push(Value::scalar_f32(0.0));
        inputs.push(Value::from_vec_i32(vec![b, s + 1], ttoks.clone()));
        rt.exec("train_tl-7s", &inputs).unwrap()
    });
    println!("{}", stats.line_throughput((b * s) as f64, "tok"));
    Ok(())
}
