//! Runtime benches: artifact dispatch latency, dense vs fused-kernel
//! forward, packed-engine forward, KV-cached incremental decode vs the
//! quadratic full re-forward it replaces (with decode weight GB/s for the
//! packed engine), train-step throughput. Runs on the XLA backend when
//! artifacts are present (and the `xla` feature is on), otherwise on the
//! native engine — no setup required.
//!
//! Usage: `cargo bench --bench bench_runtime -- [--fast] [group-filter]...`
//! (`--fast` is the CI budget; filters select groups by substring:
//! dispatch / forward / fused / packed / decode / train). Results also
//! land in machine-readable `BENCH_runtime.json`.

use odlri::benchkit::{group, BenchArgs, Bencher, JsonReport};
use odlri::corpus;
use odlri::engine::{argmax, Engine, NativeEngine};
use odlri::fused::FusedModel;
use odlri::model::ModelParams;
use odlri::runtime::{Runtime, Value};
use odlri::tensor::Matrix;
use odlri::util::rng::Pcg64;

/// `--fast` (CI) caps every case at a small budget; otherwise keep the
/// historical per-group iteration shapes (default 1s target).
fn bencher(args: &BenchArgs, name: &str, min_iters: usize, max_iters: usize) -> Bencher {
    if args.fast {
        Bencher::new(name).iters(2, 4).budget(0.08)
    } else {
        Bencher::new(name).iters(min_iters, max_iters)
    }
}

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::from_env();
    let mut json = JsonReport::new("runtime");
    let dir = odlri::runtime::default_artifact_dir();
    let rt = Runtime::open(&dir)?;
    println!(
        "engine: {}",
        if rt.is_native() { "native" } else { "xla/pjrt" }
    );
    let fam = rt.manifest.family("tl-7s")?.clone();
    let (b, s) = (rt.manifest.batch, rt.manifest.seq);
    let mut rng = Pcg64::new(1, 1);
    // Shared fixtures (cheap to build; used by several groups).
    let params = ModelParams::init(&fam, 2);
    let data = corpus::generate(corpus::Split::WikiSim, 100_000, 1);
    let toks = corpus::sample_batch(&data, b, s, &mut rng);

    if args.want("dispatch") {
        group("kernel dispatch");
        rt.warm("kernel_fused_qlr")?;
        let q = Matrix::randn(128, 128, 1.0, &mut rng);
        let l = Matrix::randn(128, 32, 1.0, &mut rng);
        let r = Matrix::randn(32, 128, 1.0, &mut rng);
        let x = Matrix::randn(128, 16, 1.0, &mut rng);
        let stats = args.bencher("kernel_fused_qlr_128").run(|| {
            rt.exec(
                "kernel_fused_qlr",
                &[
                    Value::from_matrix(&q),
                    Value::from_matrix(&l),
                    Value::from_matrix(&r),
                    Value::from_matrix(&x),
                ],
            )
            .unwrap()
        });
        println!("{}", stats.line());
        json.record(&stats);
        // Direct call without the Value boundary (dispatch overhead view).
        let bench = args.bencher("rust_fused_equivalent");
        let stats = bench.run(|| odlri::fused::qlr_matmul(&q, &l, &r, &x));
        println!("{}", stats.line());
        json.record(&stats);
    }

    if args.want("forward") {
        group("model forward (B=8, S=96)");
        rt.warm("fwd_tl-7s")?;
        let stats = bencher(&args, "fwd_tl-7s", 3, 20).run(|| {
            let mut inputs = params.values.clone();
            inputs.push(Value::from_vec_i32(vec![b, s], toks.clone()));
            rt.exec("fwd_tl-7s", &inputs).unwrap()
        });
        println!("{}", stats.line_throughput((b * s) as f64, "tok"));
        json.record_with(&stats, Some(((b * s) as f64, "tok")));
    }

    if args.want("fused") {
        group("fused deploy forward (every projection via the fused kernel)");
        rt.warm("fwd_fused_tl-7s")?;
        let rank = rt.manifest.fused_rank;
        let mut fused_inputs = params.values.clone();
        for name in &fam.projections {
            let w = params.get_matrix(name)?;
            fused_inputs.push(Value::from_matrix(&w));
            fused_inputs.push(Value::from_matrix(&Matrix::zeros(w.rows(), rank)));
            fused_inputs.push(Value::from_matrix(&Matrix::zeros(rank, w.cols())));
        }
        fused_inputs.push(Value::from_vec_i32(vec![b, s], toks.clone()));
        let stats = bencher(&args, "fwd_fused_tl-7s", 3, 20).run(|| {
            rt.exec("fwd_fused_tl-7s", &fused_inputs).unwrap()
        });
        println!("{}", stats.line_throughput((b * s) as f64, "tok"));
        json.record_with(&stats, Some(((b * s) as f64, "tok")));
    }

    if args.want("packed") {
        group("packed fused engine (bit-packed Q, dequant on the fly)");
        for bits in [2u32, 8] {
            let fm = FusedModel::pack_dense(&params, "uniform", bits, 64)?;
            let stats = bencher(&args, &format!("fused_model_q{bits}b"), 3, 20)
                .run(|| fm.forward(&toks, b, s).unwrap());
            println!(
                "{}  [{:.2} bits/weight]",
                stats.line_throughput((b * s) as f64, "tok"),
                fm.avg_bits()
            );
            json.record_with(&stats, Some(((b * s) as f64, "tok")));
        }
    }

    if args.want("decode") {
        group("incremental decode vs full re-forward (per-token cost by context length)");
        // KV-cached decode cost per token should stay roughly FLAT in the
        // generated length; re-running the full sequence per token (what
        // the old fixed-shape Forward API forced) grows linearly per token
        // — quadratic over a whole generation.
        let prompt: Vec<i32> = toks[..16].to_vec();
        let target_lens: &[usize] = if args.fast { &[48, 96] } else { &[48, 96, 192] };
        for engine_kind in ["dense", "fused-2b"] {
            let engine: Box<dyn Engine> = match engine_kind {
                "dense" => Box::new(NativeEngine::new(&params, b, s)?.with_max_context(512)),
                _ => Box::new(
                    FusedModel::pack_dense(&params, "uniform", 2, 64)?.with_shape(b, 512),
                ),
            };
            for &target_len in target_lens {
                let (mut session, logits) = engine.prefill(&prompt)?;
                let mut next = argmax(logits.row(logits.rows() - 1)) as i32;
                // Steady-state decode: mean of the last 8 steps at this
                // length.
                let mut tail_s = 0f64;
                let mut tail_n = 0usize;
                while session.tokens.len() < target_len {
                    let t0 = std::time::Instant::now();
                    let lg = engine.decode_step(&mut [&mut session], &[next])?;
                    let dt = t0.elapsed().as_secs_f64();
                    if session.tokens.len() + 8 >= target_len {
                        tail_s += dt;
                        tail_n += 1;
                    }
                    next = argmax(lg.row(0)) as i32;
                }
                let t0 = std::time::Instant::now();
                let _ = engine.forward_batch(&session.tokens, 1, session.tokens.len())?;
                let reforward_ms = t0.elapsed().as_secs_f64() * 1e3;
                let tok_s = tail_s / tail_n.max(1) as f64;
                // Packed engines also report decode weight throughput: the
                // whole packed Q payload is re-decoded every step, so GB/s
                // = q_bytes / step_seconds — the number kernel wins move.
                let gbs = match engine.decode_weight_bytes() {
                    Some(qb) if tok_s > 0.0 => {
                        format!("   [{:.2} GB/s packed Q]", qb as f64 / tok_s / 1e9)
                    }
                    _ => String::new(),
                };
                println!(
                    "{engine_kind:>8} ctx {target_len:>4}: kv-decode {:.3} ms/tok   \
                     full re-forward {:.3} ms/tok{gbs}",
                    tok_s * 1e3,
                    reforward_ms
                );
                // One decode step = one token, so throughput derives from
                // the per-iteration time.
                let thr = if tok_s > 0.0 { Some((1.0, "tok")) } else { None };
                let bench_name = format!("kvdecode_{engine_kind}_ctx{target_len}");
                json.record_value(&bench_name, tok_s * 1e9, thr);
            }
        }
    }

    if args.want("train") {
        group("train step (B=8, S=97)");
        rt.warm("train_tl-7s")?;
        let n = params.values.len();
        let zeros: Vec<Value> = params
            .values
            .iter()
            .map(|v| {
                Value::from_vec_f32(
                    v.shape().to_vec(),
                    vec![0.0; v.shape().iter().product()],
                )
            })
            .collect();
        let ttoks = corpus::sample_batch(&data, b, s + 1, &mut rng);
        let stats = bencher(&args, "train_step_tl-7s", 3, 10).run(|| {
            let mut inputs = Vec::with_capacity(3 * n + 2);
            inputs.extend(params.values.iter().cloned());
            inputs.extend(zeros.iter().cloned());
            inputs.extend(zeros.iter().cloned());
            inputs.push(Value::scalar_f32(0.0));
            inputs.push(Value::from_vec_i32(vec![b, s + 1], ttoks.clone()));
            rt.exec("train_tl-7s", &inputs).unwrap()
        });
        println!("{}", stats.line_throughput((b * s) as f64, "tok"));
        json.record_with(&stats, Some(((b * s) as f64, "tok")));
    }

    if !json.is_empty() {
        let path = json.write(std::path::Path::new("."))?;
        println!("\nwrote {}", path.display());
    }
    Ok(())
}
