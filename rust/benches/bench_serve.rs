//! Serving bench: speculative decoding (low-bit ODLRI draft proposing, the
//! target verifying each round in one batched step) vs plain target-only
//! greedy decode, on the artifact-free pack-dense pairing. Every
//! speculative run is asserted bit-identical to the plain stream before
//! its timing is reported. Results also land in machine-readable
//! `BENCH_serve.json` for the CI bench-json artifact flow.
//!
//! Usage: `cargo bench --bench bench_serve -- [--fast] [group-filter]...`
//! (`--fast` is the CI budget; filters select groups by substring:
//! speculative).

use odlri::benchkit::{group, BenchArgs, JsonReport};
use odlri::corpus;
use odlri::engine::speculative::SpeculativeEngine;
use odlri::engine::{generate, Sampling};
use odlri::fused::FusedModel;
use odlri::model::ModelParams;
use odlri::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::from_env();
    let mut json = JsonReport::new("serve");
    let rt = Runtime::open(&odlri::runtime::default_artifact_dir())?;
    let fam = rt.manifest.family("tl-7s")?.clone();
    let params = ModelParams::init(&fam, 2);
    let data = corpus::generate(corpus::Split::WikiSim, 4096, 1);
    let prompt: Vec<i32> = data[..32].iter().map(|&x| x as i32).collect();
    let max_new = if args.fast { 24 } else { 96 };
    let pack = |bits: u32| -> anyhow::Result<FusedModel> {
        Ok(FusedModel::pack_dense(&params, "uniform", bits, 64)?.with_shape(1, 256))
    };

    if args.want("speculative") {
        group("speculative vs plain greedy decode (4-bit target, 2-bit draft)");
        let target = pack(4)?;
        let plain = generate(&target, &prompt, max_new, Sampling::Greedy)?;
        let plain_secs: f64 = plain.step_latencies_s.iter().sum();
        let plain_toks = plain.tokens.len().saturating_sub(1).max(1);
        let plain_ns = plain_secs * 1e9 / plain_toks as f64;
        println!("plain 4b target: {:.3} ms/tok", plain_ns / 1e6);
        json.record_value("decode_plain_4b", plain_ns, Some((1.0, "tok")));
        for k in [2usize, 4] {
            let spec = SpeculativeEngine::new(Box::new(pack(2)?), Box::new(pack(4)?), k)?;
            let out = spec.generate(&prompt, max_new)?;
            assert_eq!(
                out.gen.tokens, plain.tokens,
                "speculative stream diverged from plain greedy (k={k})"
            );
            let secs: f64 = out.gen.step_latencies_s.iter().sum();
            let toks = out.gen.tokens.len().saturating_sub(1).max(1);
            let ns = secs * 1e9 / toks as f64;
            let c = out.counters;
            println!(
                "spec 2b draft k={k}: {:.3} ms/tok, acceptance {:.1}% \
                 ({} draft steps + {} verify steps)",
                ns / 1e6,
                c.acceptance_rate() * 100.0,
                c.draft_steps,
                c.verify_steps
            );
            json.record_value(
                &format!("decode_speculative_2b_draft_k{k}"),
                ns,
                Some((1.0, "tok")),
            );
        }
    }

    if !json.is_empty() {
        let path = json.write(std::path::Path::new("."))?;
        println!("\nwrote {}", path.display());
    }
    Ok(())
}
