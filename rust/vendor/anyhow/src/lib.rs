//! Offline, std-only shim for the `anyhow` API subset this workspace uses.
//!
//! The repository builds with zero network access, so instead of the real
//! `anyhow` crate we vendor a ~150-line drop-in covering exactly what the
//! codebase touches: [`Error`], [`Result`], the [`anyhow!`] / [`bail!`]
//! macros, and the [`Context`] extension trait on `Result` and `Option`.
//!
//! Semantics mirror upstream where it matters:
//! * `{e}` displays the outermost message only,
//! * `{e:#}` displays the whole chain separated by `": "`,
//! * `{e:?}` displays the message plus a `Caused by:` list,
//! * `?` converts any `std::error::Error + Send + Sync + 'static`.
//!
//! Error sources are flattened into owned strings at conversion time — this
//! shim intentionally drops downcasting support (unused in this workspace).

use std::error::Error as StdError;
use std::fmt;

/// `anyhow::Result<T>` — alias with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error. The chain is ordered outermost-first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an additional layer of context (becomes the new outermost
    /// message).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T, E>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: StdError + Send + Sync + 'static,
{
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// `return Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// `if !cond { bail!(..) }` — provided for completeness.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_modes() {
        let e: Error = Error::from(io_err()).context("opening manifest");
        assert_eq!(format!("{e}"), "opening manifest");
        assert_eq!(format!("{e:#}"), "opening manifest: file missing");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("file missing"));
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("ctx").unwrap_err();
        assert_eq!(format!("{e}"), "ctx");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
    }

    #[test]
    fn macros() {
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
        let n = 3;
        let e = anyhow!("got {n} items, want {}", 5);
        assert_eq!(format!("{e}"), "got 3 items, want 5");
        fn f() -> Result<()> {
            bail!("boom {}", 1)
        }
        assert_eq!(format!("{}", f().unwrap_err()), "boom 1");
        fn g() -> Result<()> {
            ensure!(1 + 1 == 3, "math broke");
            Ok(())
        }
        assert!(g().is_err());
    }
}
