//! Chaos acceptance tests through the public serving API: a seeded
//! [`FaultPlan`] must *replay* — same spec + seed ⇒ the same fault
//! sequence, the same counters, and byte-identical streams — and the
//! degradation machinery it exercises (retry-with-backoff, client-abort
//! retirement, shard quarantine + failover, the speculation breaker)
//! must keep every normally-completing request bit-identical to a
//! fault-free solo run.

use odlri::engine::replicas::Replicas;
use odlri::engine::speculative::BREAKER_THRESHOLD;
use odlri::engine::{self, NativeEngine, Priority, Request, Response, Sampling};
use odlri::fused::FusedModel;
use odlri::model::ModelParams;
use odlri::runtime::FamilySpec;
use odlri::serve::faults::FaultPlan;
use odlri::serve::{
    serve_oneshot_speculative_with, serve_oneshot_with, ServeOptions, ServeReport,
};

fn micro_params(seed: u64) -> ModelParams {
    let fam = FamilySpec::build("micro", 11, 8, 1, 2, 1, 12, "swiglu");
    ModelParams::init(&fam, seed)
}

fn micro_native(seed: u64) -> NativeEngine {
    NativeEngine::new(&micro_params(seed), 4, 8).expect("engine")
}

fn micro_fused(seed: u64) -> FusedModel {
    FusedModel::pack_dense(&micro_params(seed), "uniform", 4, 16)
        .expect("pack")
        .with_shape(2, 8)
}

/// Distinct micro-vocab prompts (tokens 1..=10) of `len` tokens each.
fn distinct_prompts(n: usize, len: usize) -> Vec<Vec<i32>> {
    (0..n)
        .map(|i| (0..len).map(|j| (1 + (i * 3 + j) % 10) as i32).collect())
        .collect()
}

fn gen_reqs(prompts: &[Vec<i32>], max_new: usize) -> Vec<Request> {
    prompts
        .iter()
        .map(|p| Request::Generate {
            prompt: p.clone(),
            max_new_tokens: max_new,
            sampling: Sampling::Greedy,
            priority: Priority::default(),
            deadline_ticks: 0,
        })
        .collect()
}

/// Every counter the chaos determinism property pins, in one comparable
/// bundle. `completed` is the full completion-order trail, so two runs
/// that merely *count* the same but order differently still fail.
fn counters(r: &ServeReport) -> (Vec<u64>, Vec<usize>) {
    (
        r.completed.clone(),
        vec![
            r.generated_tokens,
            r.rejected,
            r.timed_out,
            r.shed,
            r.aborted,
            r.pool_retries,
            r.injected_pool_faults,
            r.shard_failures,
            r.failovers,
            r.preemptions,
            r.resumes,
            r.draft_failures,
            r.breaker_trips,
            r.breaker_skipped,
            r.drafted_tokens,
            r.accepted_tokens,
            r.rejected_tokens,
        ],
    )
}

/// Token streams with the response variant encoded, so an `Aborted` in
/// one run can never pair up with a `Generated` in another.
fn streams(resps: &[Response]) -> Vec<Option<Vec<i32>>> {
    resps
        .iter()
        .map(|r| match r {
            Response::Generated { tokens, .. } => Some(tokens.clone()),
            _ => None,
        })
        .collect()
}

#[test]
fn chaos_runs_replay_bit_exactly_for_a_fixed_seed() {
    // The headline determinism property: two serves of the same request
    // list under the same chaos spec + seed produce identical counters
    // (fault draws replay) and byte-identical responses. pool=1 makes
    // every decoding request take the retry-with-backoff path at least
    // once; abort=0.4 retires a seed-chosen subset mid-stream.
    let opts = ServeOptions {
        chaos: FaultPlan::parse("pool=1,abort=0.4").unwrap(),
        chaos_seed: 9,
        ..ServeOptions::default()
    };
    let prompts = distinct_prompts(5, 8);
    let run = || {
        let engine = micro_native(33);
        serve_oneshot_with(&engine, gen_reqs(&prompts, 8), &opts).unwrap()
    };
    let (resps_a, report_a) = run();
    let (resps_b, report_b) = run();
    assert_eq!(
        counters(&report_a),
        counters(&report_b),
        "same seed, different fault sequence"
    );
    assert_eq!(streams(&resps_a), streams(&resps_b), "same seed, different streams");
    assert_eq!(report_a.completed.len(), 5, "a request went unanswered");
    assert!(
        report_a.injected_pool_faults + report_a.aborted >= 1,
        "the chaos plan injected nothing: {report_a:?}"
    );
    // Every response is a typed terminal — and the requests that did
    // complete match the fault-free solo reference token for token.
    let reference = micro_native(33);
    for (p, r) in prompts.iter().zip(&resps_a) {
        match r {
            Response::Generated { tokens, .. } => {
                let solo = engine::generate(&reference, p, 8, Sampling::Greedy).unwrap();
                assert_eq!(tokens, &solo.tokens, "chaos bent a surviving stream");
            }
            Response::Aborted => {}
            other => panic!("unexpected terminal {other:?}"),
        }
    }
    // A different seed must eventually disagree — the draws are seeded,
    // not constant. (Counters could coincide for one alternate seed by
    // chance; three alternates all colliding means the seed is ignored.)
    let differs = [10u64, 11, 12].iter().any(|&s| {
        let engine = micro_native(33);
        let alt = ServeOptions {
            chaos_seed: s,
            ..opts.clone()
        };
        let (_, rep) = serve_oneshot_with(&engine, gen_reqs(&prompts, 8), &alt).unwrap();
        counters(&rep) != counters(&report_a)
    });
    assert!(differs, "chaos seed has no effect on the fault sequence");
}

#[test]
fn request_keyed_fault_draws_are_identical_across_replica_topologies() {
    // pool and abort draws are keyed by request id, not by tick or shard,
    // so the set of requests that fault — and therefore every
    // request-keyed counter and every surviving stream — is the same
    // under 1 and 2 replicas, even though tick counts and shard routing
    // differ. (Tick-keyed sites like `replica` are deliberately excluded:
    // they are deterministic per topology, not across topologies.)
    let opts = ServeOptions {
        chaos: FaultPlan::parse("pool=1,abort=0.5").unwrap(),
        chaos_seed: 7,
        ..ServeOptions::default()
    };
    let prompts = distinct_prompts(4, 6);
    let serve_on = |shards: usize| {
        let reps = Replicas::new(micro_fused(43), shards);
        serve_oneshot_with(&reps, gen_reqs(&prompts, 6), &opts).unwrap()
    };
    let (resps_1, rep_1) = serve_on(1);
    let (resps_2, rep_2) = serve_on(2);
    for (name, a, b) in [
        ("injected_pool_faults", rep_1.injected_pool_faults, rep_2.injected_pool_faults),
        ("aborted", rep_1.aborted, rep_2.aborted),
        ("rejected", rep_1.rejected, rep_2.rejected),
        ("timed_out", rep_1.timed_out, rep_2.timed_out),
        ("shed", rep_1.shed, rep_2.shed),
        ("completed", rep_1.completed.len(), rep_2.completed.len()),
    ] {
        assert_eq!(a, b, "{name} varied with replica count ({a} vs {b})");
    }
    assert_eq!(
        streams(&resps_1),
        streams(&resps_2),
        "replica topology changed which requests survived or what they said"
    );
}

#[test]
fn shard_quarantine_mid_run_fails_over_bit_exactly() {
    // replica=1 quarantines one shard of a two-shard fleet on the first
    // tick with live sessions — mid-flight for all four (the fleet admits
    // 2 per shard). The orphaned sessions must migrate to the survivor by
    // bit-exact re-prefill, the survivor can never be quarantined, and
    // every stream still matches the fault-free solo reference.
    let opts = ServeOptions {
        chaos: FaultPlan::parse("replica=1").unwrap(),
        chaos_seed: 13,
        ..ServeOptions::default()
    };
    let reps = Replicas::new(micro_fused(47), 2);
    let prompts = distinct_prompts(4, 6);
    let (resps, report) = serve_oneshot_with(&reps, gen_reqs(&prompts, 8), &opts).unwrap();
    assert_eq!(
        report.shard_failures, 1,
        "exactly one quarantine can succeed on a two-shard fleet"
    );
    assert!(
        report.failovers >= 1,
        "the dead shard hosted sessions but none migrated: {report:?}"
    );
    assert_eq!(report.rejected, 0);
    assert_eq!(report.completed.len(), 4, "a request went unanswered");
    let reference = micro_fused(47);
    for (p, r) in prompts.iter().zip(&resps) {
        let solo = engine::generate(&reference, p, 8, Sampling::Greedy).unwrap();
        match r {
            Response::Generated { tokens, .. } => {
                assert_eq!(tokens.len(), 8, "short generation after failover");
                assert_eq!(tokens, &solo.tokens, "failover bent a stream");
            }
            other => panic!("wrong response {other:?}"),
        }
    }
}

#[test]
fn breaker_counters_replay_for_a_fixed_seed_under_draft_chaos() {
    // Speculative determinism: draft=1 fails every draft round, trips the
    // circuit breaker, and suppresses drafting — identically across two
    // runs, and without bending a single output token (failed drafts fall
    // back to plain verify-path decode).
    let opts = ServeOptions {
        chaos: FaultPlan::parse("draft=1").unwrap(),
        chaos_seed: 5,
        ..ServeOptions::default()
    };
    let prompts = distinct_prompts(3, 7);
    let run = || {
        let target = micro_native(17);
        let draft = micro_native(18);
        serve_oneshot_speculative_with(&target, &draft, 2, gen_reqs(&prompts, 8), &opts).unwrap()
    };
    let (resps_a, report_a) = run();
    let (resps_b, report_b) = run();
    assert_eq!(
        counters(&report_a),
        counters(&report_b),
        "same seed, different breaker behaviour"
    );
    assert_eq!(streams(&resps_a), streams(&resps_b));
    assert!(
        report_a.draft_failures >= BREAKER_THRESHOLD,
        "draft chaos never accumulated to the trip threshold: {report_a:?}"
    );
    assert!(report_a.breaker_trips >= 1, "breaker never tripped");
    assert_eq!(
        report_a.drafted_tokens, 0,
        "a drafted token slipped through a permanently-failing draft"
    );
    let reference = micro_native(17);
    for (p, r) in prompts.iter().zip(&resps_a) {
        let solo = engine::generate(&reference, p, 8, Sampling::Greedy).unwrap();
        match r {
            Response::Generated { tokens, .. } => {
                assert_eq!(tokens, &solo.tokens, "draft chaos bent an output stream");
            }
            other => panic!("wrong response {other:?}"),
        }
    }
}
