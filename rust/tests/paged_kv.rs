//! Paged-KV integration tests on the tl-7s family, through the public
//! serving API: budget-forced preemption with bit-exact resume, and
//! cross-session KV prefix sharing behind one shared system prompt.

use std::path::Path;
use std::time::Duration;

use odlri::engine::{self, Engine, NativeEngine, Priority, Request, Response, Sampling};
use odlri::fused::FusedModel;
use odlri::model::ModelParams;
use odlri::runtime::Runtime;
use odlri::serve::{run_server, serve_oneshot, ServeConfig, Workload};

/// tl-7s page size: 2 (K+V) · 4 layers · 16 positions · kv_dim 128 · 4 B.
const PAGE_BYTES: usize = 2 * 4 * 16 * 128 * 4;

fn tl7s(seed: u64) -> (usize, usize, ModelParams) {
    let rt = Runtime::open(Path::new("artifacts")).expect("opening runtime");
    let fam = rt.manifest.family("tl-7s").unwrap().clone();
    let params = ModelParams::init(&fam, seed);
    (rt.manifest.batch, rt.manifest.seq, params)
}

#[test]
fn serving_survives_eviction_and_stays_bit_exact() {
    // Three sessions of two prompt pages each through a 5-page pool: the
    // third prefill must wait for capacity, and growth past position 32
    // forces a preemption. Every stream still matches an unconstrained
    // solo run bit-for-bit.
    let (batch, seq, params) = tl7s(7);
    let engine = NativeEngine::new(&params, batch, seq)
        .expect("engine")
        .with_kv_budget(5 * PAGE_BYTES)
        .expect("budget");
    let reference = NativeEngine::new(&params, batch, seq).expect("reference engine");
    let prompts: Vec<Vec<i32>> = (0..3)
        .map(|i| (0..24).map(|j| ((i * 31 + j * 7) % 256) as i32).collect())
        .collect();
    let reqs: Vec<Request> = prompts
        .iter()
        .map(|p| Request::Generate {
            prompt: p.clone(),
            max_new_tokens: 16,
            sampling: Sampling::Greedy,
            priority: Priority::default(),
            deadline_ticks: 0,
        })
        .collect();
    let (resps, report) = serve_oneshot(&engine, reqs).expect("serve");
    assert!(
        report.preemptions >= 1,
        "a 5-page pool under 3x3-page demand never preempted"
    );
    assert_eq!(
        report.preemptions, report.resumes,
        "every preemption must be matched by a bit-exact resume"
    );
    for (p, r) in prompts.iter().zip(&resps) {
        let solo = engine::generate(&reference, p, 16, Sampling::Greedy).expect("solo");
        match r {
            Response::Generated { tokens, .. } => {
                assert_eq!(tokens.len(), 16, "short generation");
                assert_eq!(tokens, &solo.tokens, "evicted stream diverged from solo");
            }
            other => panic!("wrong response {other:?}"),
        }
    }
    let ps = engine.pool_stats().expect("paged engine has stats");
    assert_eq!(ps.max_pages, 5);
    assert!(
        ps.peak_resident_pages <= ps.max_pages,
        "pool over-allocated: {ps:?}"
    );
}

#[test]
fn shared_system_prompt_shares_kv_pages_across_sessions() {
    // Six closed-loop requests behind one 48-token system prompt (exactly
    // three whole pages) on the packed engine: later sessions adopt the
    // registered prefix pages instead of materializing their own copies,
    // so resident pages stay well below sessions x prompt-pages.
    let (batch, seq, params) = tl7s(9);
    let fm = FusedModel::pack_dense(&params, "uniform", 8, 64)
        .expect("pack")
        .with_shape(batch, seq);
    let cfg = ServeConfig {
        requests: 6,
        clients: 3,
        deadline: Duration::from_millis(5),
        seed: 11,
        workload: Workload::Generate { max_new_tokens: 8 },
        prompt_len: 48,
        shared_prompt: true,
        prefill_chunk: 0,
        batch_clients: 0,
        long_prompt_len: 0,
        ..ServeConfig::default()
    };
    let report = run_server(&fm, &cfg).expect("serve");
    assert_eq!(report.completed.len(), 6, "dropped requests");
    assert_eq!(report.generated_tokens, 6 * 8, "short generations");
    let ps = fm.pool_stats().expect("paged engine has stats");
    assert!(
        ps.shared_adoptions >= 3,
        "prefix sharing never engaged: {ps:?}"
    );
    assert!(
        ps.peak_resident_pages < 6 * 3,
        "resident pages not sub-linear in sessions: {ps:?}"
    );
}
