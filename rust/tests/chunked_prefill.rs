//! Chunked-prefill acceptance tests on the tl-7s family, through the
//! public engine + serving API: any sequence of `prefill_chunk` calls
//! that concatenates to the prompt must be **bit-identical** to one-shot
//! `prefill` — same logits rows, same cache, same greedy continuation —
//! on both the dense native engine and the bit-packed fused engine, and
//! KV prefix adoption must still fire when the shared prefix spans a
//! chunk boundary.

use std::path::Path;

use odlri::engine::{self, Engine, NativeEngine, Priority, Request, Response, Sampling, Session};
use odlri::fused::FusedModel;
use odlri::model::ModelParams;
use odlri::runtime::Runtime;
use odlri::serve::{serve_oneshot, serve_oneshot_chunked};

fn tl7s(seed: u64) -> (usize, usize, ModelParams) {
    let rt = Runtime::open(Path::new("artifacts")).expect("opening runtime");
    let fam = rt.manifest.family("tl-7s").unwrap().clone();
    let params = ModelParams::init(&fam, seed);
    (rt.manifest.batch, rt.manifest.seq, params)
}

fn native(seed: u64) -> NativeEngine {
    let (batch, seq, params) = tl7s(seed);
    NativeEngine::new(&params, batch, seq).expect("engine")
}

fn fused(seed: u64) -> FusedModel {
    let (batch, seq, params) = tl7s(seed);
    FusedModel::pack_dense(&params, "uniform", 8, 64)
        .expect("pack")
        .with_shape(batch, seq)
}

fn prompt_tokens(len: usize, seed: usize) -> Vec<i32> {
    (0..len).map(|j| ((seed * 31 + j * 7) % 256) as i32).collect()
}

/// Feed `prompt` through `prefill_chunk` at the given cumulative targets
/// (the last must be `prompt.len()`), asserting every chunk's logits rows
/// equal the corresponding rows of the one-shot `prefill`, then return
/// the assembled session.
fn chunked_session(engine: &dyn Engine, prompt: &[i32], targets: &[usize]) -> Session {
    let (_one, oneshot) = engine.prefill(prompt).expect("one-shot prefill");
    assert_eq!(oneshot.rows(), prompt.len());
    let mut state = None;
    let mut done = 0usize;
    for &upto in targets {
        let logits = engine
            .prefill_chunk(prompt, &mut state, upto)
            .unwrap_or_else(|e| panic!("chunk to {upto}: {e}"));
        assert_eq!(logits.rows(), upto - done, "chunk row count");
        for r in 0..logits.rows() {
            assert_eq!(
                logits.row(r),
                oneshot.row(done + r),
                "chunk row {r} (absolute {}) != one-shot prefill row",
                done + r
            );
        }
        done = upto;
    }
    assert_eq!(done, prompt.len());
    Session::new(prompt.to_vec(), state.take().expect("built cache"))
}

#[test]
fn chunk_splits_are_bit_identical_to_one_shot_on_native_engine() {
    // Page-aligned, ragged, degenerate whole-prompt, and token-at-a-time
    // splits all reproduce the monolithic prefill logits bit-for-bit and
    // decode to the same greedy stream.
    let engine = native(21);
    let prompt = prompt_tokens(40, 3);
    let reference = engine::generate(&engine, &prompt, 8, Sampling::Greedy).expect("solo");
    let splits: Vec<Vec<usize>> = vec![
        vec![40],
        vec![16, 32, 40],
        vec![7, 20, 40],
        (1..=40).collect(),
    ];
    for targets in &splits {
        let mut sess = chunked_session(&engine, &prompt, targets);
        // Greedy-decode from the chunk-built cache and compare streams.
        let mut next = {
            let (_s, logits) = engine.prefill(&prompt).expect("prefill");
            engine::argmax(logits.row(logits.rows() - 1)) as i32
        };
        let mut tokens = Vec::new();
        for _ in 0..8 {
            tokens.push(next);
            let logits = engine.decode_step(&mut [&mut sess], &[next]).expect("decode");
            next = engine::argmax(logits.row(0)) as i32;
        }
        assert_eq!(
            tokens, reference.tokens,
            "split {targets:?} changed the greedy stream"
        );
    }
}

#[test]
fn chunk_splits_are_bit_identical_to_one_shot_on_fused_engine() {
    // Same property through the packed (Q+LR) projections, whose prefill
    // kernels pick a dispatch regime by row count: the chunk path must
    // pin the one-shot regime so logits stay bit-exact at any split.
    let fm = fused(22);
    let prompt = prompt_tokens(33, 5);
    let reference = engine::generate(&fm, &prompt, 6, Sampling::Greedy).expect("solo");
    for targets in [vec![33], vec![16, 32, 33], vec![5, 11, 33]] {
        let mut sess = chunked_session(&fm, &prompt, &targets);
        let mut next = {
            let (_s, logits) = fm.prefill(&prompt).expect("prefill");
            engine::argmax(logits.row(logits.rows() - 1)) as i32
        };
        let mut tokens = Vec::new();
        for _ in 0..6 {
            tokens.push(next);
            let logits = fm.decode_step(&mut [&mut sess], &[next]).expect("decode");
            next = engine::argmax(logits.row(0)) as i32;
        }
        assert_eq!(
            tokens, reference.tokens,
            "fused split {targets:?} changed the greedy stream"
        );
    }
}

#[test]
fn chunked_serving_streams_match_one_shot_serving() {
    // End to end through the scheduler: the same request list served with
    // chunked prefill (several chunk budgets) returns byte-identical
    // token streams to monolithic-prefill serving.
    let engine = native(23);
    let mk_reqs = || -> Vec<Request> {
        (0..4)
            .map(|i| Request::Generate {
                prompt: prompt_tokens(18 + 5 * i, 40 + i),
                max_new_tokens: 6,
                sampling: Sampling::Greedy,
                priority: if i % 2 == 0 {
                    Priority::Interactive
                } else {
                    Priority::Batch
                },
                deadline_ticks: 0,
            })
            .collect()
    };
    let (want, _) = serve_oneshot(&engine, mk_reqs()).expect("one-shot serve");
    for chunk in [1usize, 4, 16, 64] {
        let (got, report) =
            serve_oneshot_chunked(&engine, mk_reqs(), chunk).expect("chunked serve");
        assert_eq!(got.len(), want.len());
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            match (a, b) {
                (
                    Response::Generated { tokens: ta, .. },
                    Response::Generated { tokens: tb, .. },
                ) => assert_eq!(ta, tb, "chunk {chunk}: request {i} stream diverged"),
                other => panic!("wrong response pair {other:?}"),
            }
        }
        assert_eq!(report.rejected, 0);
    }
}

#[test]
fn prefix_adoption_fires_across_a_chunk_boundary() {
    // A 32-token (two whole pages) system prompt registered by an earlier
    // one-shot session must still be adopted by a later *chunked* prefill
    // whose first chunk boundary falls inside the shared prefix — and the
    // adopted session's stream must stay bit-exact.
    let fm = fused(24);
    let shared = prompt_tokens(32, 9);
    let (_holder, _l) = fm.prefill(&shared).expect("register shared prefix");
    let before = fm.pool_stats().expect("pool stats").shared_adoptions;

    let mut prompt = shared.clone();
    prompt.extend(prompt_tokens(16, 77)); // distinct 16-token tail
    // Chunk boundary at 16: inside the adopted two-page extent.
    let mut sess = chunked_session(&fm, &prompt, &[16, 32, 48]);
    let after = fm.pool_stats().expect("pool stats").shared_adoptions;
    assert!(
        after > before,
        "chunked prefill never adopted the registered prefix ({before} -> {after})"
    );

    // Bit-exactness against an unshared engine built from the same params.
    let reference = fused(24);
    let want = engine::generate(&reference, &prompt, 6, Sampling::Greedy).expect("solo");
    let mut next = {
        let (_s, logits) = reference.prefill(&prompt).expect("prefill");
        engine::argmax(logits.row(logits.rows() - 1)) as i32
    };
    let mut tokens = Vec::new();
    for _ in 0..6 {
        tokens.push(next);
        let logits = fm.decode_step(&mut [&mut sess], &[next]).expect("decode");
        next = engine::argmax(logits.row(0)) as i32;
    }
    assert_eq!(tokens, want.tokens, "adopted chunked stream diverged");
}
