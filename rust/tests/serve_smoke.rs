//! Serving smoke tests: drive the continuous-batching server (the engine
//! behind `examples/serve.rs` and `odlri serve-bench`) end to end on the
//! artifact-free native fallback, over both engines — dense native and the
//! bit-packed fused `(Q+LR)·x` engine — for both workloads: full-sequence
//! scoring and KV-cached incremental generation.

use std::path::Path;
use std::time::Duration;

use odlri::engine::{self, Engine, NativeEngine, Sampling};
use odlri::fused::FusedModel;
use odlri::model::ModelParams;
use odlri::runtime::Runtime;
use odlri::serve::{run_server, ServeConfig, Workload};

fn smoke_config(requests: usize, workload: Workload) -> ServeConfig {
    ServeConfig {
        requests,
        clients: 3,
        deadline: Duration::from_millis(5),
        seed: 11,
        workload,
        prompt_len: 0,
        shared_prompt: false,
        prefill_chunk: 0,
        batch_clients: 0,
        long_prompt_len: 0,
        ..ServeConfig::default()
    }
}

fn native_engine(seed: u64) -> NativeEngine {
    let rt = Runtime::open(Path::new("artifacts")).expect("opening runtime");
    let fam = rt.manifest.family("tl-7s").unwrap().clone();
    let params = ModelParams::init(&fam, seed);
    NativeEngine::new(&params, rt.manifest.batch, rt.manifest.seq).expect("engine")
}

fn fused_engine(seed: u64) -> FusedModel {
    let rt = Runtime::open(Path::new("artifacts")).expect("opening runtime");
    let fam = rt.manifest.family("tl-7s").unwrap().clone();
    let params = ModelParams::init(&fam, seed);
    // Bit-packed projections, rank-0 factors: the serving hot path with no
    // dense W anywhere.
    FusedModel::pack_dense(&params, "uniform", 8, 64)
        .expect("pack")
        .with_shape(rt.manifest.batch, rt.manifest.seq)
}

#[test]
fn batch_server_completes_all_requests_on_native_dense_path() {
    let engine = native_engine(1);
    let report = run_server(&engine, &smoke_config(12, Workload::Score)).expect("serve");
    assert_eq!(report.scores.len(), 12, "dropped requests");
    assert_eq!(report.latencies_s.len(), 12);
    assert!(report.batches >= 2, "batching never engaged");
    for (i, s) in report.scores.iter().enumerate() {
        assert!(s.is_finite(), "request {i} got non-finite score {s}");
        // Mean NLL of a byte LM: positive, below uniform+slack.
        assert!(*s > 0.0 && *s < 10.0, "request {i} score {s} implausible");
    }
    assert!(report.latencies_s.iter().all(|&l| l > 0.0));
    assert!(report.p95_ms() >= report.p50_ms());
}

#[test]
fn batch_server_completes_on_packed_fused_engine() {
    let fm = fused_engine(2);
    let report = run_server(&fm, &smoke_config(10, Workload::Score)).expect("serve fused");
    assert_eq!(report.scores.len(), 10, "dropped requests");
    for (i, s) in report.scores.iter().enumerate() {
        assert!(s.is_finite(), "request {i} got non-finite score {s}");
        assert!(*s > 0.0 && *s < 10.0, "request {i} score {s} implausible");
    }
    assert!(report.requests_per_sec() > 0.0);
}

#[test]
fn generation_workload_serves_kv_cached_decoding_on_fused_engine() {
    let fm = fused_engine(3);
    let mut cfg = smoke_config(6, Workload::Generate { max_new_tokens: 8 });
    cfg.prompt_len = 24;
    let report = run_server(&fm, &cfg).expect("serve generation");
    assert_eq!(report.completed.len(), 6, "dropped requests");
    assert_eq!(report.generated_tokens, 6 * 8, "short generations");
    assert!(report.decode_steps >= 7, "decode batching never engaged");
    assert_eq!(report.decode_steps, report.decode_step_latencies_s.len());
    assert!(report.decode_tokens_per_sec() > 0.0);
}

#[test]
fn greedy_decode_is_deterministic_across_worker_counts() {
    // The packed kernels block/thread over weight rows, the dense matmuls
    // over output rows — per-element accumulation order never changes, so
    // greedy generation must be bit-deterministic across thread budgets.
    let engine = native_engine(4);
    let prompt: Vec<i32> = (0..24).map(|i| (i * 7 % 200) as i32).collect();
    odlri::tensor::set_matmul_threads(1);
    let a = engine::generate(&engine, &prompt, 12, Sampling::Greedy).expect("gen t1");
    odlri::tensor::set_matmul_threads(4);
    let b = engine::generate(&engine, &prompt, 12, Sampling::Greedy).expect("gen t4");
    odlri::tensor::set_matmul_threads(0);
    assert_eq!(a.tokens, b.tokens, "thread count changed greedy decode");

    let fm = fused_engine(4);
    odlri::tensor::set_matmul_threads(1);
    let fa = engine::generate(&fm, &prompt, 12, Sampling::Greedy).expect("fused t1");
    odlri::tensor::set_matmul_threads(4);
    let fb = engine::generate(&fm, &prompt, 12, Sampling::Greedy).expect("fused t4");
    odlri::tensor::set_matmul_threads(0);
    assert_eq!(fa.tokens, fb.tokens, "thread count changed fused greedy decode");
}

#[test]
fn prefill_plus_decode_matches_full_forward_on_native_engine() {
    // The generation acceptance contract at the engine level: scoring a
    // generated continuation with a full-sequence forward reproduces the
    // incremental logits bit-for-bit.
    let engine = native_engine(5);
    let prompt: Vec<i32> = (0..16).map(|i| (i * 13 % 250) as i32).collect();
    let out = engine::generate(&engine, &prompt, 6, Sampling::Greedy).expect("gen");
    let mut history = prompt.clone();
    for &tok in &out.tokens {
        let logits = engine
            .forward_batch(&history, 1, history.len())
            .expect("forward");
        let want = engine::argmax(logits.row(history.len() - 1)) as i32;
        assert_eq!(tok, want, "KV decode diverged from full forward");
        history.push(tok);
    }
}
