//! Serving smoke test: drives the dynamic-batching batch-server loop
//! (the engine behind `examples/serve.rs`) end to end on the artifact-free
//! native fallback, over both forward paths — dense runtime and the
//! bit-packed fused `(Q+LR)·x` engine.

use std::path::Path;
use std::time::Duration;

use odlri::eval::RuntimeForward;
use odlri::fused::FusedModel;
use odlri::model::ModelParams;
use odlri::runtime::Runtime;
use odlri::serve::{run_batch_server, ServeConfig};

fn smoke_config(requests: usize) -> ServeConfig {
    ServeConfig {
        requests,
        clients: 3,
        deadline: Duration::from_millis(5),
        seed: 11,
    }
}

#[test]
fn batch_server_completes_all_requests_on_native_dense_path() {
    let rt = Runtime::open(Path::new("artifacts")).expect("opening runtime");
    let fam = rt.manifest.family("tl-7s").unwrap().clone();
    let params = ModelParams::init(&fam, 1);
    let fwd = RuntimeForward {
        rt: &rt,
        params: &params,
    };
    let report = run_batch_server(&fwd, &smoke_config(12)).expect("serve");
    assert_eq!(report.scores.len(), 12, "dropped requests");
    assert_eq!(report.latencies_s.len(), 12);
    assert!(report.batches >= 2, "batching never engaged");
    for (i, s) in report.scores.iter().enumerate() {
        assert!(s.is_finite(), "request {i} got non-finite score {s}");
        // Mean NLL of a byte LM: positive, below uniform+slack.
        assert!(*s > 0.0 && *s < 10.0, "request {i} score {s} implausible");
    }
    assert!(report.latencies_s.iter().all(|&l| l > 0.0));
    assert!(report.p95_ms() >= report.p50_ms());
}

#[test]
fn batch_server_completes_on_packed_fused_engine() {
    let rt = Runtime::open(Path::new("artifacts")).expect("opening runtime");
    let fam = rt.manifest.family("tl-7s").unwrap().clone();
    let params = ModelParams::init(&fam, 2);
    // Bit-packed projections, rank-0 factors: the serving hot path with no
    // dense W anywhere.
    let fm = FusedModel::pack_dense(&params, "uniform", 8, 64).expect("pack");
    let report = run_batch_server(&fm, &smoke_config(10)).expect("serve fused");
    assert_eq!(report.scores.len(), 10, "dropped requests");
    for (i, s) in report.scores.iter().enumerate() {
        assert!(s.is_finite(), "request {i} got non-finite score {s}");
        assert!(*s > 0.0 && *s < 10.0, "request {i} score {s} implausible");
    }
    assert!(report.requests_per_sec() > 0.0);
}
