//! Integration tests across the three layers: the runtime executing the
//! artifact entry points (kernels + model paths), the calibration/
//! compression/eval pipeline, and end-to-end composition checks.
//!
//! These run **artifact-free**: without `artifacts/` the runtime serves the
//! same artifact names through the native engine, so the whole suite
//! exercises the real train/calibrate/compress/eval/serve stack. With
//! `artifacts/` present (and the `xla` feature), the identical assertions
//! run against the AOT HLO artifacts instead.

use std::path::Path;

use odlri::calib::{calibrate, CalibConfig};
use odlri::coordinator::{
    BudgetPlanner, CompressionPipeline, CompressionPlan, InitKind, PipelineConfig, Planner,
};
use odlri::corpus;
use odlri::engine::NativeEngine;
use odlri::eval;
use odlri::fused::FusedModel;
use odlri::model::{inject_outliers, ModelParams};
use odlri::runtime::{Runtime, Value};
use odlri::tensor::Matrix;
use odlri::train::{train, TrainConfig};
use odlri::util::rng::Pcg64;

// Each test builds its own runtime — cheap on the native engine, and the
// PJRT client (when the xla feature is on) is not Sync anyway.
fn runtime() -> Runtime {
    Runtime::open(Path::new("artifacts")).expect("opening runtime")
}

// ---------------------------------------------------------------- kernels

#[test]
fn kernel_quantize_matches_rust_quantizer() {
    let rt = runtime();
    let mut rng = Pcg64::new(1, 1);
    let w = Matrix::randn(128, 128, 2.0, &mut rng);
    let outs = rt
        .exec("kernel_quantize", &[Value::from_matrix(&w)])
        .expect("exec kernel_quantize");
    let got = outs[0].to_matrix().unwrap();
    // The kernel is 4-bit group-32 — identical semantics to the Rust
    // UniformQuantizer(4, 32).
    use odlri::quant::Quantizer as _;
    let want = odlri::quant::UniformQuantizer::new(4, 32).quantize(&w).deq;
    assert!(
        got.max_abs_diff(&want) < 1e-4,
        "kernel vs rust quantizer diff = {}",
        got.max_abs_diff(&want)
    );
}

#[test]
fn kernel_fused_qlr_matches_rust_matmul() {
    let rt = runtime();
    let mut rng = Pcg64::new(2, 1);
    let q = Matrix::randn(128, 128, 1.0, &mut rng);
    let l = Matrix::randn(128, 32, 1.0, &mut rng);
    let r = Matrix::randn(32, 128, 1.0, &mut rng);
    let x = Matrix::randn(128, 16, 1.0, &mut rng);
    let outs = rt
        .exec(
            "kernel_fused_qlr",
            &[
                Value::from_matrix(&q),
                Value::from_matrix(&l),
                Value::from_matrix(&r),
                Value::from_matrix(&x),
            ],
        )
        .expect("exec kernel_fused_qlr");
    let got = outs[0].to_matrix().unwrap();
    let want = q.add(&l.dot(&r)).dot(&x);
    assert!(got.rel_err(&want) < 1e-4, "rel err {}", got.rel_err(&want));
}

#[test]
fn kernel_fwht_matches_rust_fwht() {
    let rt = runtime();
    let mut rng = Pcg64::new(3, 1);
    let w = Matrix::randn(128, 128, 1.0, &mut rng);
    let outs = rt
        .exec("kernel_fwht", &[Value::from_matrix(&w)])
        .expect("exec kernel_fwht");
    let got = outs[0].to_matrix().unwrap();
    let mut want = w.clone();
    odlri::hadamard::fwht_rows(&mut want);
    assert!(got.rel_err(&want) < 1e-4);
}

// ------------------------------------------------------------ model paths

fn quick_train(rt: &Runtime, steps: usize) -> ModelParams {
    train(
        rt,
        &TrainConfig {
            family: "tl-7s".into(),
            steps,
            corpus_tokens: 120_000,
            seed: 7,
            log_every: 0,
        },
    )
    .expect("training")
    .params
}

#[test]
fn forward_runs_and_is_finite() {
    let rt = runtime();
    let fam = rt.manifest.family("tl-7s").unwrap();
    let params = ModelParams::init(fam, 5);
    let (b, s) = (rt.manifest.batch, rt.manifest.seq);
    let data = corpus::generate(corpus::Split::WikiSim, 50_000, 1);
    let mut rng = Pcg64::new(4, 4);
    let toks = corpus::sample_batch(&data, b, s, &mut rng);
    let mut inputs = params.values.clone();
    inputs.push(Value::from_vec_i32(vec![b, s], toks));
    let outs = rt.exec("fwd_tl-7s", &inputs).expect("fwd");
    let logits = outs[0].to_matrix_2d().unwrap();
    assert_eq!(logits.shape(), (b * s, fam.vocab));
    assert!(logits.is_finite());
}

#[test]
fn training_reduces_loss_e2e() {
    let rt = runtime();
    let result = train(
        &rt,
        &TrainConfig {
            family: "tl-7s".into(),
            steps: 25,
            corpus_tokens: 120_000,
            seed: 3,
            log_every: 0,
        },
    )
    .expect("train");
    let first = result.losses[0].1;
    let last = result.losses.last().unwrap().1;
    // 25 AdamW steps on the templated byte corpus must make clear progress
    // from the ~ln(256) starting point.
    assert!(
        last < first - 0.7,
        "loss did not drop: {first} → {last}"
    );
}

#[test]
fn untrained_ppl_near_uniform() {
    let rt = runtime();
    let fam = rt.manifest.family("tl-7s").unwrap();
    let params = ModelParams::init(fam, 6);
    let engine = NativeEngine::new(&params, rt.manifest.batch, rt.manifest.seq).unwrap();
    let ppl = eval::perplexity(&engine, corpus::Split::WikiSim, 6, 42).unwrap();
    // Byte-uniform would be 256; random init is close (the corpus is
    // lowercase ASCII, so logits are uninformative).
    assert!(ppl > 60.0 && ppl < 600.0, "ppl={ppl}");
}

#[test]
fn calibration_hessians_cover_all_projections() {
    let rt = runtime();
    let fam = rt.manifest.family("tl-7s").unwrap();
    let params = ModelParams::init(fam, 8);
    let hessians = calibrate(
        &rt,
        &params,
        &CalibConfig {
            batches: 2,
            seed: 1,
        },
    )
    .expect("calibrate");
    assert_eq!(hessians.len(), fam.projections.len());
    for name in &fam.projections {
        let h = &hessians[name];
        let in_dim = fam.param_shape(name).unwrap()[1];
        assert_eq!(h.dim(), in_dim, "{name}");
        assert!(h.samples > 0);
        // PSD-ish: diagonal positive.
        assert!(h.matrix().diag().iter().all(|&d| d >= 0.0), "{name}");
    }
}

#[test]
fn outlier_injection_preserves_model_function() {
    // Logits before and after injection must match (function-preserving).
    let rt = runtime();
    let params = quick_train(&rt, 8);
    let (b, s) = (rt.manifest.batch, rt.manifest.seq);
    let data = corpus::generate(corpus::Split::WikiSim, 50_000, 2);
    let mut rng = Pcg64::new(5, 5);
    let toks = corpus::sample_batch(&data, b, s, &mut rng);

    let run = |p: &ModelParams| {
        let mut inputs = p.values.clone();
        inputs.push(Value::from_vec_i32(vec![b, s], toks.clone()));
        rt.exec("fwd_tl-7s", &inputs).unwrap()[0]
            .to_matrix_2d()
            .unwrap()
    };
    let before = run(&params);
    let mut injected = params.clone();
    inject_outliers(&mut injected, 4, 16.0, 11).unwrap();
    let after = run(&injected);
    assert!(
        after.rel_err(&before) < 1e-3,
        "outlier injection changed the function: rel err {}",
        after.rel_err(&before)
    );
}

#[test]
fn fused_forward_matches_dense_forward() {
    // The fused (Q, L, R) deploy graph must agree with the dense forward
    // when Q + LR == W exactly.
    let rt = runtime();
    let fam = rt.manifest.family("tl-7s").unwrap().clone();
    let params = ModelParams::init(&fam, 12);
    let (b, s) = (rt.manifest.batch, rt.manifest.seq);
    let rank = rt.manifest.fused_rank;
    let data = corpus::generate(corpus::Split::C4Sim, 50_000, 3);
    let mut rng = Pcg64::new(6, 6);
    let toks = corpus::sample_batch(&data, b, s, &mut rng);

    // Dense logits.
    let mut inputs = params.values.clone();
    inputs.push(Value::from_vec_i32(vec![b, s], toks.clone()));
    let dense = rt.exec("fwd_tl-7s", &inputs).unwrap()[0]
        .to_matrix_2d()
        .unwrap();

    // Fused with Q = W − LR for random small LR.
    let mut fused_inputs = params.values.clone();
    for name in &fam.projections {
        let w = params.get_matrix(name).unwrap();
        let l = Matrix::randn(w.rows(), rank, 0.02, &mut rng);
        let r = Matrix::randn(rank, w.cols(), 0.02, &mut rng);
        let q = w.sub(&l.dot(&r));
        fused_inputs.push(Value::from_matrix(&q));
        fused_inputs.push(Value::from_matrix(&l));
        fused_inputs.push(Value::from_matrix(&r));
    }
    fused_inputs.push(Value::from_vec_i32(vec![b, s], toks));
    let fused = rt.exec("fwd_fused_tl-7s", &fused_inputs).unwrap()[0]
        .to_matrix_2d()
        .unwrap();
    assert!(
        fused.rel_err(&dense) < 5e-3,
        "fused vs dense rel err {}",
        fused.rel_err(&dense)
    );
}

#[test]
fn packed_fused_model_tracks_dense_eval() {
    // The serving engine (bit-packed Q, dequant-on-the-fly kernels) must
    // reproduce the dense eval path's perplexity when packing is
    // near-lossless (8-bit).
    let rt = runtime();
    let fam = rt.manifest.family("tl-7s").unwrap();
    let params = ModelParams::init(fam, 17);
    let engine = NativeEngine::new(&params, rt.manifest.batch, rt.manifest.seq).unwrap();
    let ppl_dense = eval::perplexity(&engine, corpus::Split::WikiSim, 4, 42).unwrap();
    let fm = FusedModel::pack_dense(&params, "uniform", 8, 64)
        .unwrap()
        .with_shape(rt.manifest.batch, rt.manifest.seq);
    let ppl_fused = eval::perplexity(&fm, corpus::Split::WikiSim, 4, 42).unwrap();
    let ratio = ppl_fused / ppl_dense;
    assert!(
        (0.95..1.05).contains(&ratio),
        "fused ppl {ppl_fused} vs dense {ppl_dense}"
    );
}

#[test]
fn compress_then_eval_beats_random_and_tracks_fp32() {
    // Tiny end-to-end: short train → calibrate → ODLRI compress → eval.
    let rt = runtime();
    let mut params = quick_train(&rt, 20);
    inject_outliers(&mut params, 4, 16.0, 3).unwrap();
    let hessians = calibrate(
        &rt,
        &params,
        &CalibConfig {
            batches: 2,
            seed: 2,
        },
    )
    .unwrap();
    let cfg = PipelineConfig {
        init: InitKind::Odlri,
        rank: 8,
        lr_bits: 16,
        outer_iters: 3,
        lplr_iters: 2,
        workers: 4,
        ..Default::default()
    };
    let out = CompressionPipeline::new(cfg).run(&params, &hessians).unwrap();
    let applied = out.model.apply_to(&params).unwrap();

    let (batch, seq) = (rt.manifest.batch, rt.manifest.seq);
    let fp_engine = NativeEngine::new(&params, batch, seq).unwrap();
    let ppl_fp = eval::perplexity(&fp_engine, corpus::Split::WikiSim, 6, 42).unwrap();
    let q_engine = NativeEngine::new(&applied, batch, seq).unwrap();
    let ppl_q = eval::perplexity(&q_engine, corpus::Split::WikiSim, 6, 42).unwrap();
    // Compressed is worse than FP32 but far better than an untrained model.
    let fam = rt.manifest.family("tl-7s").unwrap();
    let random = ModelParams::init(fam, 99);
    let rand_engine = NativeEngine::new(&random, batch, seq).unwrap();
    let ppl_rand = eval::perplexity(&rand_engine, corpus::Split::WikiSim, 6, 42).unwrap();
    assert!(ppl_q >= ppl_fp * 0.99, "ppl_q={ppl_q} ppl_fp={ppl_fp}");
    assert!(
        ppl_q < ppl_rand * 0.7,
        "compression destroyed the model: {ppl_q} vs random {ppl_rand}"
    );

    // The packed fused serving form carries the pipeline's Q bit-exactly
    // (scheme-native codes), so it tracks the dense reconstruction's
    // perplexity up to kernel summation order.
    let fm = out.model.to_fused(&params).unwrap();
    for (name, cm) in &out.model.matrices {
        assert_eq!(
            fm.mats[name].q.unpack().max_abs_diff(&cm.q),
            0.0,
            "{name}: deployed Q differs from the optimized Q"
        );
    }
    let ppl_fused = eval::perplexity(&fm, corpus::Split::WikiSim, 6, 42).unwrap();
    assert!(
        ppl_fused < ppl_q * 1.1 + 1.0,
        "fused serving diverged: {ppl_fused} vs {ppl_q}"
    );
}

#[test]
fn budget_plan_compress_serves_odf3_end_to_end() {
    // The full heterogeneous path: train → calibrate → budget-plan →
    // compress → ODF3 container → fused serving. The budget is a hard
    // ceiling the reported model bits must respect.
    let rt = runtime();
    let mut params = quick_train(&rt, 15);
    inject_outliers(&mut params, 4, 16.0, 3).unwrap();
    let hessians = calibrate(
        &rt,
        &params,
        &CalibConfig {
            batches: 2,
            seed: 2,
        },
    )
    .unwrap();
    let base = PipelineConfig {
        init: InitKind::Odlri,
        rank: 8,
        lr_bits: 4,
        outer_iters: 2,
        lplr_iters: 2,
        workers: 4,
        ..Default::default()
    };
    let fam = rt.manifest.family("tl-7s").unwrap();
    // Budget strictly between the planner's floor (rank 2) and the full
    // uniform plan (rank 8), so the allocation must discriminate.
    let lo = CompressionPlan::uniform(
        fam,
        &PipelineConfig {
            rank: 2,
            ..base.clone()
        },
    )
    .avg_bits(fam)
    .unwrap();
    let hi = CompressionPlan::uniform(fam, &base).avg_bits(fam).unwrap();
    assert!(lo < hi);
    let budget = 0.5 * (lo + hi);
    let plan = BudgetPlanner::new(budget, base.clone())
        .plan(&params, &hessians)
        .unwrap();
    assert!(plan.avg_bits(fam).unwrap() <= budget + 1e-9);
    let (rlo, rhi) = plan.rank_spread();
    assert!(rlo < rhi, "budget plan should be heterogeneous, got r{rlo}..r{rhi}");

    let out = CompressionPipeline::new(base)
        .run_plan(&params, &hessians, &plan)
        .unwrap();
    assert!(
        out.model.avg_bits() <= budget + 1e-9,
        "reported {:.4} bits over budget {budget:.4}",
        out.model.avg_bits()
    );
    let fm = out.model.to_fused(&params).unwrap();
    let dir = std::env::temp_dir().join("odlri_test_budget_odf");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tl-7s.budget.odf");
    fm.save(&path).unwrap();
    let loaded = odlri::fused::FusedModel::load(fam, &path).unwrap();
    std::fs::remove_file(&path).ok();
    // Plan metadata survives deployment, heterogeneity intact.
    assert_eq!(loaded.plans, fm.plans);
    let ranks: Vec<usize> = loaded.plans.values().map(|p| p.rank).collect();
    assert!(ranks.iter().any(|r| *r != ranks[0]));
    // Mixed-precision decode actually serves: perplexity is finite and
    // tracks the dense reconstruction.
    let applied = out.model.apply_to(&params).unwrap();
    let (batch, seq) = (rt.manifest.batch, rt.manifest.seq);
    let dense = NativeEngine::new(&applied, batch, seq).unwrap();
    let ppl_dense = eval::perplexity(&dense, corpus::Split::WikiSim, 4, 42).unwrap();
    let ppl_fused = eval::perplexity(&loaded, corpus::Split::WikiSim, 4, 42).unwrap();
    assert!(ppl_fused.is_finite() && ppl_dense.is_finite());
    assert!(
        ppl_fused < ppl_dense * 1.1 + 1.0,
        "fused heterogeneous serving diverged: {ppl_fused} vs {ppl_dense}"
    );
}

#[test]
fn pipeline_error_restores_matmul_thread_cap() {
    // The coordinator caps matmuls to one thread while its worker pool is
    // wide, via a counted RAII scope that never touches the configured
    // thread budget. An early error return (here: a projection the params
    // cannot deliver) must release the cap and leave the configured value
    // untouched — the historical leak left the whole process pinned
    // single-threaded.
    let rt = runtime();
    let mut fam = rt.manifest.family("tl-7s").unwrap().clone();
    fam.projections.push("layer0.missing".into());
    let params = ModelParams::init(&fam, 11);
    let mut hessians = std::collections::BTreeMap::new();
    for name in &fam.projections {
        let n = fam
            .param_shape(name)
            .map(|s| s[1])
            .unwrap_or(fam.d_model);
        hessians.insert(name.clone(), odlri::hessian::Hessian::zeros(n));
    }
    odlri::tensor::set_matmul_threads(5);
    let scopes_before = odlri::tensor::matmul_single_scopes();
    let pipe = CompressionPipeline::new(PipelineConfig {
        rank: 2,
        outer_iters: 1,
        lplr_iters: 1,
        workers: 4,
        ..Default::default()
    });
    assert!(pipe.run(&params, &hessians).is_err());
    assert_eq!(
        odlri::tensor::matmul_threads(),
        5,
        "the pipeline clobbered the configured matmul thread budget"
    );
    odlri::tensor::set_matmul_threads(0);
    // The errored run's scope must have been released. Other tests in this
    // binary may hold their own scopes concurrently, so poll (bounded)
    // until the count returns to the baseline; a genuine leak never drains.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    while odlri::tensor::matmul_single_scopes() > scopes_before
        && std::time::Instant::now() < deadline
    {
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    assert!(
        odlri::tensor::matmul_single_scopes() <= scopes_before,
        "early pipeline error leaked a single-thread matmul scope"
    );
}

#[test]
fn task_scoring_pipeline_runs() {
    let rt = runtime();
    let params = quick_train(&rt, 15);
    let engine = NativeEngine::new(&params, rt.manifest.batch, rt.manifest.seq).unwrap();
    for task in corpus::ALL_TASKS {
        let score = eval::task_accuracy(&engine, task, 16, 5).unwrap();
        assert_eq!(score.items, 16);
        assert!((0.0..=1.0).contains(&score.accuracy), "{task:?}");
    }
}
